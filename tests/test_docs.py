"""Documentation contract: the public API is documented and the docs are
true. Docstring checks cover every symbol exported from ``repro.core``,
``repro.core.engine``, ``repro.core.serving``, ``repro.core.batch``,
``repro.core.runner``, ``repro.dist``, ``repro.serve`` and
``repro.pgm.datasets``; the code blocks in ``docs/engine.md``,
``docs/serving.md``, ``docs/admission.md``, ``docs/router.md`` and
``docs/workloads.md`` are executed verbatim (they are the living spec of
the engine, the serving tiers and the workload zoo); relative links
between the markdown files
must resolve, and README's doc table must link every file in ``docs/``."""

import inspect
import pathlib
import re

import pytest

DOCS = pathlib.Path(__file__).resolve().parent.parent / "docs"
REPO = DOCS.parent

PUBLIC_MODULES = ["repro.core", "repro.core.engine", "repro.core.serving",
                  "repro.core.batch", "repro.core.runner", "repro.dist",
                  "repro.serve", "repro.pgm.datasets", "repro.kernels.ops",
                  "repro.kernels.triton_update",
                  "repro.roofline.kernel_model"]


def _public_objects(modname):
    mod = pytest.importorskip(modname)
    assert hasattr(mod, "__all__"), f"{modname} must declare __all__"
    for name in mod.__all__:
        yield name, getattr(mod, name)


@pytest.mark.parametrize("modname", PUBLIC_MODULES)
def test_public_symbols_have_real_docstrings(modname):
    missing = []
    for name, obj in _public_objects(modname):
        if not (inspect.isclass(obj) or inspect.isroutine(obj)):
            continue    # constants, registries, re-exported modules
        doc = inspect.getdoc(obj) or ""
        # Reject the dataclass auto-docstring ("Name(field: type = ...)")
        # and one-word stubs: shapes/semantics need actual sentences.
        if len(doc) < 40 or doc.startswith(f"{name}("):
            missing.append(name)
    assert not missing, f"{modname}: undocumented public symbols: {missing}"


def _code_blocks(md_path):
    text = md_path.read_text()
    return re.findall(r"```python\n(.*?)```", text, flags=re.DOTALL)


@pytest.mark.parametrize("md,min_blocks", [("engine.md", 3),
                                           ("serving.md", 3),
                                           ("admission.md", 3),
                                           ("schedulers.md", 2),
                                           ("router.md", 3),
                                           ("workloads.md", 3),
                                           ("kernels.md", 3)])
def test_md_code_blocks_execute(md, min_blocks):
    blocks = _code_blocks(DOCS / md)
    assert len(blocks) >= min_blocks, f"{md} lost its executable examples"
    # Doc examples register demo schedulers/policies into the process-global
    # registries; snapshot and restore so later tests see pristine families.
    from repro.core.schedulers import SCHEDULERS
    from repro.core.serving import ADMISSION_POLICIES
    from repro.kernels.ops import BATCH_UPDATE_BACKENDS, UPDATE_BACKENDS
    from repro.pgm.datasets import WORKLOADS
    from repro.serve.routing import ROUTING_POLICIES
    registries = (SCHEDULERS, UPDATE_BACKENDS, BATCH_UPDATE_BACKENDS,
                  ADMISSION_POLICIES, ROUTING_POLICIES, WORKLOADS)
    snapshots = [dict(r) for r in registries]
    ns = {}
    try:
        for i, block in enumerate(blocks):
            try:
                exec(compile(block, f"docs/{md}[block {i}]", "exec"), ns)
            except Exception as e:     # pragma: no cover - failure reporting
                pytest.fail(f"docs/{md} block {i} failed: {e!r}\n{block}")
    finally:
        for reg, snap in zip(registries, snapshots):
            reg.clear()
            reg.update(snap)


@pytest.mark.parametrize("md", ["README.md", "docs/architecture.md",
                                "docs/schedulers.md", "docs/engine.md",
                                "docs/sharding.md", "docs/serving.md",
                                "docs/admission.md", "docs/router.md",
                                "docs/workloads.md", "docs/kernels.md"])
def test_relative_links_resolve(md):
    path = REPO / md
    broken = []
    for target in re.findall(r"\]\(([^)#]+?)(?:#[^)]*)?\)", path.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        resolved = (path.parent / target).resolve()
        if not resolved.is_relative_to(REPO):
            continue    # GitHub-UI paths (badge/actions) live off-repo
        if not resolved.exists():
            broken.append(target)
    assert not broken, f"{md}: broken relative links: {broken}"


def test_readme_links_every_doc():
    """README's doc-links table is the docs' front door: every markdown
    file under docs/ must be linked from it (CI's docs job enforces the
    same for serving.md via grep)."""
    readme = (REPO / "README.md").read_text()
    missing = [f"docs/{p.name}" for p in sorted(DOCS.glob("*.md"))
               if f"docs/{p.name}" not in readme]
    assert not missing, f"README.md does not link: {missing}"
