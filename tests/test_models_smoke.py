"""Per-arch smoke tests (REDUCED configs, same code paths): one forward /
train step / prefill / decode on CPU asserting shapes + finite values.
Full configs are exercised only via the dry-run (ShapeDtypeStruct)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get
from repro.data import SyntheticLM
from repro.configs.base import TRAIN_4K
from repro.models import build_model
from repro.train.step import init_train_state, make_train_step


def _batch(cfg, b=2, s=32, seed=0):
    key = jax.random.key(seed)
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab),
    }
    if cfg.frontend == "vision":
        batch["frontend_embeds"] = 0.1 * jax.random.normal(
            ks[2], (b, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    if cfg.frontend == "audio":
        batch["frontend_embeds"] = 0.1 * jax.random.normal(
            ks[2], (b, s, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_train_step(self, arch):
        cfg = get(arch).reduced()
        model = build_model(cfg)
        loss, metrics = jax.jit(model.forward_train)(
            model.init_params(jax.random.key(0)), _batch(cfg))
        assert np.isfinite(float(loss))
        assert float(loss) > 0

    def test_prefill_decode_shapes(self, arch):
        cfg = get(arch).reduced()
        model = build_model(cfg)
        params = model.init_params(jax.random.key(0))
        b, s = 2, 32
        batch = _batch(cfg, b, s)
        logits, cache = jax.jit(model.prefill)(params, batch)
        assert logits.shape == (b, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        dcache = model.init_cache(b, 16)
        lg, dcache2 = jax.jit(model.decode_step)(
            params, dcache, batch["tokens"][:, :1], jnp.int32(0))
        assert lg.shape == (b, cfg.padded_vocab)
        assert np.isfinite(np.asarray(lg, np.float32)).all()
        # cache pytree structure preserved
        assert jax.tree.structure(dcache) == jax.tree.structure(dcache2)


class TestTrainingConvergence:
    def test_loss_decreases_small_model(self):
        cfg = get("starcoder2_3b").reduced()
        model = build_model(cfg)
        state = init_train_state(model, jax.random.key(0))
        shape = dataclasses.replace(TRAIN_4K, seq_len=64, global_batch=8)
        pipe = SyntheticLM(cfg, shape)
        step = jax.jit(make_train_step(model, base_lr=1e-3, warmup=5,
                                       total_steps=60))
        first = last = None
        for i in range(25):
            state, metrics = step(state, pipe.batch(i))
            if i == 0:
                first = float(metrics["loss"])
            last = float(metrics["loss"])
        assert last < first - 0.2, (first, last)

    def test_microbatch_equivalence(self):
        """grad accumulation over 2 microbatches == single batch step."""
        cfg = get("qwen3_4b").reduced()
        model = build_model(cfg)
        state = init_train_state(model, jax.random.key(1))
        shape = dataclasses.replace(TRAIN_4K, seq_len=32, global_batch=4)
        batch = SyntheticLM(cfg, shape).batch(0)
        s1, m1 = jax.jit(make_train_step(model, microbatches=1))(state, batch)
        s2, m2 = jax.jit(make_train_step(model, microbatches=2))(state, batch)
        np.testing.assert_allclose(float(m1["xent"]), float(m2["xent"]),
                                   rtol=1e-4)
        # params close after one step (grad-mean == batch-grad)
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))),
            s1.params, s2.params)
        assert max(jax.tree.leaves(d)) < 5e-3


class TestDecodePrefillConsistency:
    @pytest.mark.parametrize("arch", ["qwen3_4b", "mamba2_130m",
                                      "hymba_1_5b", "deepseek_v3_671b"])
    def test_decode_matches_forward(self, arch):
        """Greedy decode logits at position t must match a fresh forward
        pass over the same prefix (cache correctness)."""
        cfg = get(arch).reduced()
        model = build_model(cfg)
        params = model.init_params(jax.random.key(0))
        b, s = 1, 8
        toks = jax.random.randint(jax.random.key(5), (b, s), 0, cfg.vocab)
        # full forward logits at last position
        logits_full, _ = jax.jit(model.prefill)(params, {"tokens": toks})
        # token-by-token decode
        cache = model.init_cache(b, s + 4)
        decode = jax.jit(model.decode_step)
        lg = None
        for t in range(s):
            lg, cache = decode(params, cache, toks[:, t:t + 1],
                               jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(lg, np.float32), np.asarray(logits_full, np.float32),
            atol=2e-2, rtol=2e-2)
