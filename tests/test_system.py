"""System-level behaviour: distributed BP parity (multi-device subprocess),
checkpoint/restore, data-pipeline determinism, fault-tolerance paths."""

import dataclasses
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_pytree, save_pytree
from repro.configs import get
from repro.configs.base import TRAIN_4K
from repro.core import RnBP, run_bp
from repro.data import SyntheticLM
from repro.ft import ElasticMesh, StragglerMonitor, run_bp_resilient
from repro.pgm import ising_grid


class TestDistributedBP:
    def test_sharded_bp_matches_single_device(self):
        """Runs in a subprocess with 8 forced host devices (device count is
        locked at first jax use, so it cannot be set in-process)."""
        code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import RnBP, LBP, run_bp
from repro.pgm import ising_grid
from repro.dist import make_bp_mesh, run_bp_sharded

pgm = ising_grid(16, 2.5, seed=0)
mesh = make_bp_mesh()
ref = run_bp(pgm, LBP(), jax.random.key(0), eps=1e-6, max_rounds=4000)
assert bool(ref.converged)
for sched in [LBP(), RnBP(low_p=0.7)]:
    res = run_bp_sharded(pgm, sched, mesh, jax.random.key(0), eps=1e-6,
                         max_rounds=4000)
    assert bool(res.converged), type(sched).__name__
    d = float(jnp.max(jnp.abs(jnp.where(pgm.state_mask,
                                        res.beliefs - ref.beliefs, 0.0))))
    assert d < 5e-3, (type(sched).__name__, d)
print("OK")
"""
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=900)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout

    def test_sharded_relaxed_scheduler_converges(self):
        """rlx/rlxtree under the 8-device sharded backend: converge to the
        single-device beliefs, and chunked resume stays bitwise (the relaxed
        per-queue selection must survive the shard_map backend)."""
        code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import LBP, run_bp
from repro.pgm import ising_grid
from repro.dist import make_bp_mesh, make_sharded_engine, shard_pgm

pgm = ising_grid(16, 2.5, seed=0)
mesh = make_bp_mesh()
ref = run_bp(pgm, LBP(), jax.random.key(0), eps=1e-6, max_rounds=4000)
assert bool(ref.converged)
spgm = shard_pgm(pgm, mesh)
for name in ["rlx", "rlxtree"]:
    engine = make_sharded_engine(name, mesh, eps=1e-6, max_rounds=20000)
    mono = engine.run(spgm, jax.random.key(7))
    assert bool(mono.converged), name
    d = float(jnp.max(jnp.abs(jnp.where(pgm.state_mask,
                                        mono.beliefs - ref.beliefs, 0.0))))
    assert d < 5e-3, (name, d)
    state = engine.init(spgm, jax.random.key(7))
    while not engine.finished(state):
        state = engine.step(state, chunk_rounds=37)
    chunked = engine.result(state)
    assert int(mono.rounds) == int(chunked.rounds), name
    np.testing.assert_array_equal(np.asarray(mono.logm),
                                  np.asarray(chunked.logm))
print("OK")
"""
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=900)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout

    def test_sharded_chunked_resume_bitwise(self):
        """Chunked BPEngine.step under the 8-device mesh must match a
        monolithic sharded run bit-for-bit -- the engine's resume guarantee
        has to survive the shard_map backend."""
        code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.pgm import ising_grid
from repro.dist import make_bp_mesh, make_sharded_engine, shard_pgm

pgm = ising_grid(12, 2.5, seed=0)
mesh = make_bp_mesh()
assert mesh.devices.size == 8
engine = make_sharded_engine("rnbp", mesh, eps=1e-4, max_rounds=1200)
spgm = shard_pgm(pgm, mesh)
mono = engine.run(spgm, jax.random.key(7))

state = engine.init(spgm, jax.random.key(7))
while not engine.finished(state):
    state = engine.step(state, chunk_rounds=23)
chunked = engine.result(state)

assert bool(mono.converged) and bool(chunked.converged)
assert int(mono.rounds) == int(chunked.rounds)
np.testing.assert_array_equal(np.asarray(mono.logm),
                              np.asarray(chunked.logm))
np.testing.assert_array_equal(np.asarray(mono.beliefs),
                              np.asarray(chunked.beliefs))
print("OK")
"""
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=900)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout

    def test_batched_bucket_through_sharded_fold(self):
        """run_many with backend='sharded' routes whole buckets through the
        mesh-aware disjoint-union fold; per-graph beliefs must match the
        single-device engine within the sharded tolerance."""
        code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.core import BPConfig, BPEngine
from repro.pgm import ising_grid
from repro.dist import make_bp_mesh, make_sharded_engine

mesh = make_bp_mesh()
assert mesh.devices.size == 8
pgms = [ising_grid(10 + (i % 3), 2.0, seed=i) for i in range(6)]
sharded = make_sharded_engine("rnbp", mesh, eps=1e-4, max_rounds=1500)
ref = BPEngine(BPConfig(scheduler="rnbp", eps=1e-4, max_rounds=1500))
res_s = sharded.run_many(pgms, jax.random.key(3))
res_r = ref.run_many(pgms, jax.random.key(3))
for s, r in zip(res_s, res_r):
    assert bool(s.converged) and bool(r.converged)
    d = float(jnp.max(jnp.abs(s.beliefs - r.beliefs)))
    assert d < 5e-3, d
print("OK")
"""
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=900)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout

    def test_async_serving_through_sharded_backend(self):
        """The async pipeline composes with backend='sharded': every
        resident bucket's union grid is laid out over the mesh, and
        evacuation + compaction work unchanged (per-graph beliefs match the
        single-device pipeline within the sharded tolerance)."""
        code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.core import BPConfig, BPEngine, serve_async
from repro.pgm import ising_grid
from repro.dist import make_bp_mesh, make_sharded_engine

mesh = make_bp_mesh()
assert mesh.devices.size == 8
fast = [ising_grid(8, 1.5, seed=s) for s in range(5)]
stream = fast[:2] + [ising_grid(8, 3.5, seed=0)] + fast[2:]
kw = dict(max_batch=3, chunk_rounds=48, compact=True, slots=2)
sharded = make_sharded_engine("lbp", mesh, eps=1e-5, max_rounds=192)
ref = BPEngine(BPConfig(scheduler="lbp", eps=1e-5, max_rounds=192))
rep_s = serve_async(sharded, stream, jax.random.key(0), **kw)
rep_r = serve_async(ref, stream, jax.random.key(0), **kw)
assert rep_s.stats.compactions >= 1 and rep_s.stats.evacuated == len(stream)
for s, r in zip(rep_s.results, rep_r.results):
    assert int(s.rounds) == int(r.rounds)
    d = float(jnp.max(jnp.abs(s.beliefs - r.beliefs)))
    assert d < 5e-3, d
print("OK")
"""
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=900)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout


class TestCheckpoint:
    def test_save_restore_roundtrip(self):
        tree = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
                "b": {"c": jnp.ones((5,), jnp.bfloat16)}}
        with tempfile.TemporaryDirectory() as d:
            save_pytree(d, 7, tree, extra={"note": "x"})
            assert latest_step(d) == 7
            like = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
            got, extra = restore_pytree(d, 7, like)
            assert extra == {"note": "x"}
            np.testing.assert_array_equal(np.asarray(got["a"]),
                                          np.asarray(tree["a"]))

    def test_crash_mid_save_keeps_previous(self):
        tree = {"a": jnp.zeros((2,))}
        with tempfile.TemporaryDirectory() as d:
            save_pytree(d, 1, tree)
            os.makedirs(os.path.join(d, "step_000000002.tmp"))
            assert latest_step(d) == 1    # stale .tmp ignored

    def test_train_state_resume_exact(self):
        cfg = get("starcoder2_3b").reduced()
        from repro.models import build_model
        from repro.train.step import init_train_state, make_train_step
        model = build_model(cfg)
        state = init_train_state(model, jax.random.key(0))
        shape = dataclasses.replace(TRAIN_4K, seq_len=32, global_batch=4)
        pipe = SyntheticLM(cfg, shape)
        step = jax.jit(make_train_step(model))
        for i in range(3):
            state, _ = step(state, pipe.batch(i))
        with tempfile.TemporaryDirectory() as d:
            save_pytree(d, 3, state, extra={"data_step": 3})
            like = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            restored, extra = restore_pytree(d, 3, like)
            s_a, _ = step(state, pipe.batch(extra["data_step"]))
            s_b, _ = step(restored, pipe.batch(extra["data_step"]))
            diff = jax.tree.map(
                lambda a, b: float(jnp.max(jnp.abs(
                    a.astype(jnp.float32) - b.astype(jnp.float32)))),
                s_a.params, s_b.params)
            assert max(jax.tree.leaves(diff)) == 0.0


class TestDataPipeline:
    def test_deterministic_across_restarts(self):
        cfg = get("qwen3_4b").reduced()
        shape = dataclasses.replace(TRAIN_4K, seq_len=64, global_batch=4)
        a = SyntheticLM(cfg, shape, seed=3).batch(17)
        b = SyntheticLM(cfg, shape, seed=3).batch(17)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))

    def test_tokens_in_range(self):
        cfg = get("qwen3_4b").reduced()
        shape = dataclasses.replace(TRAIN_4K, seq_len=64, global_batch=2)
        b = SyntheticLM(cfg, shape).batch(0)
        assert int(b["tokens"].max()) < cfg.vocab
        assert int(b["tokens"].min()) >= 0
        assert b["tokens"].shape == b["labels"].shape

    def test_learnable_structure(self):
        cfg = get("qwen3_4b").reduced()
        shape = dataclasses.replace(TRAIN_4K, seq_len=512, global_batch=2)
        b = SyntheticLM(cfg, shape).batch(0)
        t = np.asarray(b["tokens"])
        hits = np.mean(t[:, 1:] == (t[:, :-1] * 7 + 13) % cfg.vocab)
        # coin=0.5, and the source token itself survives its own coin with
        # p=0.5 -> expected bigram hit rate ~0.25 (>> chance 1/vocab)
        assert hits > 0.2


class TestFaultTolerance:
    def test_resilient_bp_chunked_converges_and_resumes(self):
        pgm = ising_grid(12, 2.5, seed=1)
        mono = run_bp(pgm, RnBP(low_p=0.7), jax.random.key(0), eps=1e-4,
                      max_rounds=2000)
        with tempfile.TemporaryDirectory() as d:
            chunked = run_bp_resilient(pgm, RnBP(low_p=0.7),
                                       jax.random.key(0), eps=1e-4,
                                       max_rounds=2000, rounds_per_chunk=37,
                                       ckpt_dir=d)
            assert bool(chunked.converged) == bool(mono.converged)
            again = run_bp_resilient(pgm, RnBP(low_p=0.7),
                                     jax.random.key(0), eps=1e-4,
                                     max_rounds=2000, rounds_per_chunk=37,
                                     ckpt_dir=d)
            assert int(again.rounds) == 0   # crash-resume: nothing to redo

    def test_resilient_bp_restores_legacy_checkpoint(self):
        """A pre-engine checkpoint ({logm, sstate} only) must resume, not
        crash the crash-recovery path: messages carry over, the chunked run
        finishes from there."""
        from repro.core import BPConfig, BPEngine
        pgm = ising_grid(10, 2.0, seed=3)
        sched = RnBP(low_p=0.7)
        engine = BPEngine(BPConfig(scheduler=sched, eps=1e-4,
                                   max_rounds=2000, chunk_rounds=10))
        state = engine.step(engine.init(pgm, jax.random.key(0)))
        with tempfile.TemporaryDirectory() as d:
            save_pytree(d, int(state.rounds),
                        {"logm": state.logm, "sstate": state.sched_state},
                        extra={"rounds": int(state.rounds)})
            res = run_bp_resilient(pgm, sched, jax.random.key(0), eps=1e-4,
                                   max_rounds=2000, rounds_per_chunk=40,
                                   ckpt_dir=d)
            assert bool(res.converged)
            assert int(res.rounds) > 0      # resumed and did new work

    def test_straggler_monitor(self):
        mon = StragglerMonitor(budget_factor=2.0)
        assert not mon.record(1.0)
        assert not mon.record(1.1)
        assert mon.record(5.0)
        assert mon.events == 1
        assert 0.9 < mon.ewma < 1.2     # EWMA not poisoned by the outlier

    def test_elastic_mesh_single_device(self):
        em = ElasticMesh(model_parallel=4)
        mesh = em.current()             # 1 device -> degrades gracefully
        assert mesh.devices.size == len(jax.devices())
        assert not em.changed()
