"""Unified BPEngine API: config/registry round-trips, exact wrapper parity,
bit-identical chunked resume, and converged-graph evacuation.

The contracts under test:
  * ``BPEngine.run`` reproduces ``run_bp``/``run_bp_batch`` trajectories
    exactly (same ``logm``, ``rounds``, ``updates``) for all 4 schedulers;
  * N rounds via repeated ``step`` == N rounds in one ``run``, bitwise
    (the chunked-resume path the old ``_init_logm`` backdoor never tested);
  * ``serve`` with evacuation matches ``run_many`` per-graph results while
    releasing fast graphs early and cutting wasted sweeps vs. the
    run-to-completion baseline.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BPConfig, BPEngine, BatchedPGM, LBP, RBP, RS, RnBP,
                        batch_keys, get_scheduler, list_schedulers, run_bp,
                        run_bp_batch, run_bp_many, run_srbp, scheduler_spec)
from repro.pgm import chain_graph, ising_grid

SCHEDULER_SPECS = [
    ("lbp", {}),
    ("rbp", {"p": 1.0 / 16}),
    ("rs", {"p": 0.05}),
    ("rnbp", {"low_p": 0.4, "high_p": 0.9}),
    ("rlx", {"queues": 8, "sample": 0.5, "p": 1.0 / 32}),
    ("rlxtree", {"queues": 8, "sample": 0.5, "p": 1.0 / 32}),
]
IDS = [s for s, _ in SCHEDULER_SPECS]


def small_batch():
    return BatchedPGM.from_pgms([ising_grid(5, 2.0, seed=3),
                                 chain_graph(30, seed=4),
                                 chain_graph(60, seed=5)])


class TestConfigAndRegistry:
    def test_registry_resolves_specs(self):
        assert isinstance(get_scheduler("lbp"), LBP)
        assert get_scheduler("rnbp", low_p=0.2).low_p == 0.2
        rbp = RBP(p=0.5)
        assert get_scheduler(rbp) is rbp
        with pytest.raises(KeyError):
            get_scheduler("nope")
        with pytest.raises(ValueError):
            get_scheduler(rbp, p=0.1)  # kwargs need a string spec
        with pytest.raises(ValueError):
            get_scheduler("srbp")      # serial baseline, not a scheduler

    def test_scheduler_spec_roundtrip(self):
        name, kw = scheduler_spec(RnBP(low_p=0.3))
        assert name == "rnbp" and kw["low_p"] == 0.3
        assert get_scheduler(name, **kw) == RnBP(low_p=0.3)

    def test_config_serializable_end_to_end(self):
        cfg = BPConfig(scheduler="rnbp", scheduler_kwargs={"low_p": 0.4},
                       eps=1e-4, max_rounds=100, chunk_rounds=10)
        d = cfg.to_dict()
        import json
        assert BPConfig.from_dict(json.loads(json.dumps(d))) == cfg
        # instance schedulers serialize through the reverse registry
        d2 = BPConfig(scheduler=RBP(p=0.25)).to_dict()
        assert d2["scheduler"] == "rbp"
        assert d2["scheduler_kwargs"]["p"] == 0.25

    def test_config_validates(self):
        with pytest.raises(ValueError):
            BPConfig(eps=0.0)
        with pytest.raises(ValueError):
            BPConfig(damping=1.0)
        with pytest.raises(ValueError):
            BPConfig(chunk_rounds=0)

    def test_spec_roundtrip_every_registered_scheduler(self):
        # scheduler_spec(get_scheduler(name, **kw)) is the identity for
        # every registered name, including the relaxed family.
        kw_by_name = dict(SCHEDULER_SPECS)
        for name in list_schedulers():
            kw = kw_by_name.get(name, {})
            sched = get_scheduler(name, **kw)
            got_name, got_kw = scheduler_spec(sched)
            assert got_name == name
            assert get_scheduler(got_name, **got_kw) == sched
            for k, v in kw.items():
                assert got_kw[k] == v

    def test_duplicate_registration_raises(self):
        from repro.core import SCHEDULERS, register_scheduler
        with pytest.raises(ValueError, match="duplicate scheduler"):
            register_scheduler("rlx")(type(get_scheduler("rlx")))
        # deliberate replacement works and restores cleanly
        cls = SCHEDULERS["rlx"]
        assert register_scheduler("rlx", overwrite=True)(cls) is cls

    def test_registries_share_list_and_error_format(self):
        import re
        from repro.core import (get_admission_policy, list_admission_policies,
                                list_backends)
        from repro.kernels.ops import get_update_fn
        from repro.serve import get_routing_policy, list_routing_policies
        assert "rlx" in list_schedulers() and "rlxtree" in list_schedulers()
        assert "sharded" in list_backends()
        assert "pallas" in list_backends(batched=True)
        assert "fifo" in list_admission_policies()
        assert list_routing_policies() == ["deadline", "kind_affinity",
                                          "least_loaded", "round_robin"]
        fmt = r"unknown [\w ]+ 'nope'; registered: \["
        for fn in (lambda: get_scheduler("nope"),
                   lambda: get_update_fn("nope"),
                   lambda: get_update_fn("nope", batched=True),
                   lambda: get_admission_policy("nope"),
                   lambda: get_routing_policy("nope")):
            with pytest.raises(KeyError) as ei:
                fn()
            assert re.search(fmt, str(ei.value)), str(ei.value)

    def test_config_carries_relaxed_kwargs_bitwise(self):
        import json
        kw = {"queues": 16, "sample": 0.3, "p": 1.0 / 3.0}
        for name in ("rlx", "rlxtree"):
            cfg = BPConfig(scheduler=name, scheduler_kwargs=kw)
            rt = BPConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
            assert rt == cfg
            sched = rt.make_scheduler()
            assert sched.queues == 16
            assert sched.sample == 0.3
            assert sched.p == 1.0 / 3.0  # exact float, not approx


class TestWrapperParity:
    """Acceptance: BPEngine.run == run_bp / run_bp_batch exactly."""

    @pytest.mark.parametrize("spec,kw", SCHEDULER_SPECS, ids=IDS)
    def test_single_graph(self, spec, kw):
        pgm = ising_grid(6, 2.5, seed=1)
        engine = BPEngine(BPConfig(scheduler=spec, scheduler_kwargs=kw,
                                   eps=1e-4, max_rounds=300))
        res = engine.run(pgm, jax.random.key(0))
        old = run_bp(pgm, get_scheduler(spec, **kw), jax.random.key(0),
                     eps=1e-4, max_rounds=300)
        assert int(res.rounds) == int(old.rounds)
        assert int(res.updates) == int(old.updates)
        np.testing.assert_array_equal(np.asarray(res.logm),
                                      np.asarray(old.logm))

    @pytest.mark.parametrize("spec,kw", SCHEDULER_SPECS, ids=IDS)
    def test_batched(self, spec, kw):
        batch = small_batch()
        keys = batch_keys(jax.random.key(2), batch)
        engine = BPEngine(BPConfig(scheduler=spec, scheduler_kwargs=kw,
                                   eps=1e-4, max_rounds=300, history=False))
        res = engine.run(batch, keys)
        old = run_bp_batch(batch, get_scheduler(spec, **kw), keys,
                           eps=1e-4, max_rounds=300)
        np.testing.assert_array_equal(np.asarray(res.rounds),
                                      np.asarray(old.rounds))
        np.testing.assert_array_equal(np.asarray(res.updates),
                                      np.asarray(old.updates))
        np.testing.assert_array_equal(np.asarray(res.logm),
                                      np.asarray(old.logm))

    def test_updates_counts_in_integers(self):
        """Satellite: committed-message counter is uint32 (exact), not f32
        (which lost precision past ~16M)."""
        res = BPEngine(BPConfig(max_rounds=50)).run(
            ising_grid(6, 2.0, seed=0), jax.random.key(0))
        assert res.updates.dtype == jnp.uint32

    def test_deprecated_wrappers_warn(self):
        pgm = chain_graph(10, seed=0)
        with pytest.warns(DeprecationWarning, match="BPEngine"):
            run_bp(pgm, LBP(), jax.random.key(0), max_rounds=5)
        with pytest.warns(DeprecationWarning, match="BPEngine"):
            run_bp_batch(BatchedPGM.from_pgms([pgm]), LBP(),
                         jax.random.key(0), max_rounds=5)
        with pytest.warns(DeprecationWarning, match="BPEngine"):
            run_bp_many([pgm], LBP(), jax.random.key(0), max_rounds=5)
        with pytest.warns(DeprecationWarning, match="BPEngine"):
            run_srbp(pgm, eps=1e-2)

    def test_srbp_through_engine(self):
        pgm = ising_grid(5, 2.0, seed=7)
        engine = BPEngine(BPConfig(scheduler="srbp", eps=1e-4,
                                   scheduler_kwargs={"time_limit_s": 30.0}))
        res = engine.run(pgm)
        assert res.converged
        with pytest.raises(NotImplementedError):
            engine.init(pgm, jax.random.key(0))


class TestChunkedResume:
    """Satellite: N rounds in one ``run`` vs the same N via repeated
    ``step`` must be bit-identical (logm, rounds, updates) -- the chunk
    boundary must carry the full trajectory, RNG stream included."""

    @pytest.mark.parametrize("spec,kw", SCHEDULER_SPECS, ids=IDS)
    def test_single_graph_bitwise(self, spec, kw):
        pgm = ising_grid(6, 2.5, seed=1)
        engine = BPEngine(BPConfig(scheduler=spec, scheduler_kwargs=kw,
                                   eps=1e-4, max_rounds=300))
        mono = engine.run(pgm, jax.random.key(0))
        state = engine.init(pgm, jax.random.key(0))
        steps = 0
        while not engine.finished(state):
            state = engine.step(state, chunk_rounds=17)  # odd: RS overshoots
            steps += 1
        assert steps > 1, "graph converged within one chunk; weak test"
        chunked = engine.result(state)
        assert int(chunked.rounds) == int(mono.rounds)
        assert int(chunked.updates) == int(mono.updates)
        np.testing.assert_array_equal(np.asarray(chunked.logm),
                                      np.asarray(mono.logm))
        np.testing.assert_array_equal(
            np.asarray(chunked.unconverged_history),
            np.asarray(mono.unconverged_history))

    @pytest.mark.parametrize("spec,kw", SCHEDULER_SPECS, ids=IDS)
    def test_batched_bitwise(self, spec, kw):
        batch = small_batch()
        keys = batch_keys(jax.random.key(2), batch)
        engine = BPEngine(BPConfig(scheduler=spec, scheduler_kwargs=kw,
                                   eps=1e-4, max_rounds=300, history=False))
        mono = engine.run(batch, keys)
        state = engine.init(batch, keys)
        while not engine.finished(state):
            state = engine.step(state, chunk_rounds=13)
        chunked = engine.result(state)
        np.testing.assert_array_equal(np.asarray(chunked.rounds),
                                      np.asarray(mono.rounds))
        np.testing.assert_array_equal(np.asarray(chunked.updates),
                                      np.asarray(mono.updates))
        np.testing.assert_array_equal(np.asarray(chunked.logm),
                                      np.asarray(mono.logm))

    def test_step_noop_after_convergence(self):
        engine = BPEngine(BPConfig(scheduler="lbp", eps=1e-4,
                                   max_rounds=500))
        state = engine.init(chain_graph(20, seed=1), jax.random.key(0))
        while not engine.finished(state):
            state = engine.step(state)
        again = engine.step(state)
        assert int(again.rounds) == int(state.rounds)
        assert int(again.chunk_iters) == 0
        np.testing.assert_array_equal(np.asarray(again.logm),
                                      np.asarray(state.logm))


class TestServeEvacuation:
    """Satellite: a bucket with one deliberately slow graph must release its
    fast graphs after the first chunk, and wasted sweeps must drop vs. the
    no-evacuation baseline."""

    def _stream(self):
        # LBP deterministic: C=1.5 converges in tens of rounds,
        # ising(8, 3.5, seed=0) stalls to max_rounds. Same shape -> same
        # bucket key -> one backfill pool.
        fast = [ising_grid(8, 1.5, seed=s) for s in range(8)]
        return fast[:4] + [ising_grid(8, 3.5, seed=0)] + fast[4:], 4

    def test_fast_graphs_released_early_and_waste_drops(self):
        stream, slow_i = self._stream()
        engine = BPEngine(BPConfig(scheduler="lbp", eps=1e-5,
                                   max_rounds=320, history=False))
        kw = dict(max_batch=3, chunk_rounds=64)
        evac = engine.serve(stream, jax.random.key(0), evacuate=True, **kw)
        base = engine.serve(stream, jax.random.key(0), evacuate=False, **kw)
        # the slow graph stalls; every fast graph converges
        assert not bool(evac.results[slow_i].converged)
        assert all(bool(r.converged)
                   for i, r in enumerate(evac.results) if i != slow_i)
        # fast graphs sharing the straggler's initial bucket leave at the
        # first chunk boundary instead of waiting for the straggler
        first_chunk = [g for c, g in evac.stats.evacuation_log if c == 1]
        assert len(first_chunk) >= 2
        last_evac = {g: c for c, g in evac.stats.evacuation_log}
        assert last_evac[slow_i] == max(last_evac.values())
        # evacuation + backfill strictly reduce wasted and total sweeps
        assert evac.stats.backfilled > 0
        assert evac.stats.wasted_sweeps < base.stats.wasted_sweeps
        assert evac.stats.device_sweeps < base.stats.device_sweeps
        assert evac.stats.useful_sweeps == base.stats.useful_sweeps

    def test_serve_matches_run_many_exactly(self):
        """Backfilled slots must reproduce solo trajectories: serve() and
        run_many() (same fold_in keys) agree bitwise per graph."""
        stream, _ = self._stream()
        engine = BPEngine(BPConfig(scheduler="rnbp",
                                   scheduler_kwargs={"low_p": 0.4},
                                   eps=1e-4, max_rounds=320, history=False))
        rep = engine.serve(stream, jax.random.key(3), max_batch=3,
                           chunk_rounds=48)
        ref = engine.run_many(stream, jax.random.key(3), max_batch=3)
        assert len(rep.results) == len(stream)
        for got, want in zip(rep.results, ref):
            assert int(got.rounds) == int(want.rounds)
            assert int(got.updates) == int(want.updates)
            np.testing.assert_array_equal(np.asarray(got.logm),
                                          np.asarray(want.logm))

    def test_serve_heterogeneous_stream(self):
        """Mixed shapes split into independent backfill pools; results come
        back in input order, and the evacuating path matches the
        run-to-completion baseline bitwise (both pad to group ceilings, so
        stochastic schedulers see identical padded shapes)."""
        stream = [ising_grid(6, 2.0, seed=1), chain_graph(40, seed=2),
                  ising_grid(7, 2.0, seed=3), chain_graph(50, seed=4),
                  chain_graph(45, seed=5), chain_graph(60, seed=6)]
        engine = BPEngine(BPConfig(scheduler="rnbp",
                                   scheduler_kwargs={"low_p": 0.4},
                                   eps=1e-4, max_rounds=400, history=False))
        kw = dict(max_batch=2, chunk_rounds=32)
        rep = engine.serve(stream, jax.random.key(0), evacuate=True, **kw)
        base = engine.serve(stream, jax.random.key(0), evacuate=False, **kw)
        assert all(r is not None for r in rep.results)
        assert all(bool(r.converged) for r in rep.results)
        for got, want in zip(rep.results, base.results):
            assert int(got.rounds) == int(want.rounds)
            np.testing.assert_array_equal(np.asarray(got.logm),
                                          np.asarray(want.logm))
        assert rep.stats.useful_sweeps == base.stats.useful_sweeps

    def test_resume_via_state_replace(self):
        """BPState is a plain pytree: swapping fields (the checkpoint path)
        resumes exactly."""
        engine = BPEngine(BPConfig(scheduler="rnbp",
                                   scheduler_kwargs={"low_p": 0.7},
                                   eps=1e-4, max_rounds=300))
        pgm = ising_grid(6, 2.5, seed=2)
        state = engine.init(pgm, jax.random.key(1))
        state = engine.step(state, chunk_rounds=20)
        # round-trip through raw host arrays (what a checkpoint does)
        raw = jax.tree.map(np.asarray, dataclasses.replace(
            state, rng=jax.random.key_data(state.rng)))
        revived = dataclasses.replace(
            jax.tree.map(jnp.asarray, raw),
            rng=jax.random.wrap_key_data(jnp.asarray(raw.rng)))
        a = engine.run(pgm, state=state)
        b = engine.run(pgm, state=revived)
        assert int(a.rounds) == int(b.rounds)
        np.testing.assert_array_equal(np.asarray(a.logm), np.asarray(b.logm))
