"""Workload-zoo acceptance: generator properties, exact-reference
conformance per domain, and the heterogeneous serving stream.

- Property tests (hypothesis, optional extra -- the property classes skip
  via ``pytest.importorskip`` when it is missing; the structural tests
  below them always run): every registered generator yields a PGM with
  valid edge indices, strictly positive potentials (finite log-potentials
  on valid states), in-bounds state counts, and is deterministic under a
  fixed seed; ``pad_pgm`` to bucket ceilings is trajectory-inert.
- Differential conformance: small LDPC codewords decoded by the
  max-product backend match the exact MAP read off
  ``brute_force_marginals``/``ve_marginals`` (``repro.core.exact``); small
  stereo grids match exact marginals within tolerance for *every*
  registered scheduler (``list_schedulers()``, so new registrations are
  auto-covered).
- Heterogeneous-stream regression: the mixed ``zoo_stream`` through
  ``serve_async`` under each admission policy, and through the router
  tier under each routing policy (stealing on and off), is bitwise
  identical per request to solo ``BPEngine.run`` calls on identically
  padded graphs.
"""

import jax
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                       # degrade: property tests skip
    def given(*_a, **_k):
        return lambda f: f

    def settings(*_a, **_k):
        return lambda f: f

    class st:  # noqa: N801 - stand-in namespace, never executed
        integers = sampled_from = staticmethod(lambda *a, **k: None)

from repro.core import (BPConfig, BPEngine, list_admission_policies,
                        list_schedulers, serve_async)
from repro.core.batch import bucket_shape
from repro.core.exact import brute_force_marginals, ve_marginals
from repro.core.graph import NEG_INF, pad_pgm
from repro.core.messages import beliefs, map_assignment
from repro.pgm import (WORKLOADS, ldpc_code, list_workloads, stereo_mrf,
                       zoo_stream)
from repro.serve import list_routing_policies, serve_routed

#: small, fast size kwargs per kind -- property/structure tests sweep these
_SMALL = {
    "ising": dict(n=4),
    "chain": dict(n=12),
    "protein": dict(n_vertices=10),
    "ldpc": dict(n=12, dv=2, dc=4),
    "stereo": dict(height=4, width=5, n_disp=3),
}


def _check_pgm(pgm):
    """Structural invariants every zoo PGM must satisfy."""
    nv, ne = int(pgm.n_real_vertices), int(pgm.n_real_edges)
    src = np.asarray(pgm.edge_src)
    dst = np.asarray(pgm.edge_dst)
    rev = np.asarray(pgm.edge_rev)
    emask = np.asarray(pgm.edge_mask)
    smask = np.asarray(pgm.state_mask)
    nstates = np.asarray(pgm.n_states)
    assert int(emask.sum()) == ne
    assert np.all(src[emask] < nv) and np.all(dst[emask] < nv)
    assert np.all(src[emask] >= 0) and np.all(dst[emask] >= 0)
    # directed-pair convention: rev is an involution mapping real edges to
    # real edges, never to themselves
    real = np.flatnonzero(emask)
    assert np.array_equal(rev[rev[real]], real)
    assert np.all(rev[real] != real)
    # state counts in bounds and consistent with the state mask
    assert np.all(nstates[:nv] >= 2)
    assert np.all(nstates <= smask.shape[1])
    assert np.array_equal(smask.sum(axis=1), np.maximum(nstates, 1))
    # positive potentials: finite log-potentials on every valid entry
    lpv = np.asarray(pgm.log_psi_v)
    assert np.all(np.isfinite(lpv[smask]))
    lpe = np.asarray(pgm.log_psi_e)
    valid = (smask[src][:, :, None] & smask[dst][:, None, :]
             & emask[:, None, None])
    assert np.all(lpe[valid] > NEG_INF)
    assert np.all(np.isfinite(lpe[valid]))


class TestZooProperties:
    """Hypothesis sweeps over seeds: structural validity and determinism
    hold for every registered generator, not just the default seeds."""

    # class-scoped: a function-scoped autouse fixture would trip
    # Hypothesis's function_scoped_fixture health check when installed
    @pytest.fixture(autouse=True, scope="class")
    def _require_hypothesis(self):
        pytest.importorskip("hypothesis")

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16),
           kind=st.sampled_from(sorted(_SMALL)))
    def test_generators_structurally_valid(self, seed, kind):
        _check_pgm(WORKLOADS[kind](seed=seed, **_SMALL[kind]))

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_ldpc_code_is_regular(self, seed):
        inst = ldpc_code(12, dv=2, dc=4, seed=seed)
        counts = np.zeros(inst.n_bits, dtype=int)
        for members in inst.checks:
            assert len(set(members)) == len(members) == 4
            for b in members:
                counts[b] += 1
        assert np.all(counts == 2)          # every bit in exactly dv checks

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_stereo_scene_in_bounds(self, seed):
        inst = stereo_mrf(4, 5, 3, seed=seed)
        assert inst.truth.shape == inst.obs.shape == (4, 5)
        assert inst.truth.min() >= 0 and inst.truth.max() < 3
        assert np.all(inst.unary > 0) and np.all(inst.pairwise > 0)
        assert inst.accuracy(inst.truth) == 1.0


class TestZooStructure:
    """Always-run structural checks (no hypothesis dependency)."""

    @pytest.mark.parametrize("kind", sorted(_SMALL))
    def test_default_and_small_instances_valid(self, kind):
        _check_pgm(WORKLOADS[kind](seed=0, **_SMALL[kind]))
        _check_pgm(WORKLOADS[kind](seed=3))

    @pytest.mark.parametrize("kind", sorted(_SMALL))
    def test_deterministic_under_fixed_seed(self, kind):
        a = WORKLOADS[kind](seed=5, **_SMALL[kind])
        b = WORKLOADS[kind](seed=5, **_SMALL[kind])
        for la, lb in zip(jax.tree_util.tree_leaves(a),
                          jax.tree_util.tree_leaves(b)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        c = WORKLOADS[kind](seed=6, **_SMALL[kind])
        assert not np.array_equal(np.asarray(a.log_psi_v),
                                  np.asarray(c.log_psi_v))

    def test_zoo_stream_mixes_kinds_and_sizes(self):
        items = list(zoo_stream(9, seed=0))
        kinds = {k for k, _ in items}
        assert kinds == set(list_workloads())
        shapes = {(int(p.n_edges), int(p.n_vertices)) for _, p in items}
        assert len(shapes) > len(kinds)     # sizes vary within kinds too
        with pytest.raises(KeyError):
            list(zoo_stream(2, kinds=["nope"]))
        only = list(zoo_stream(4, kinds=["ldpc", "stereo"]))
        assert {k for k, _ in only} == {"ldpc", "stereo"}

    @pytest.mark.parametrize("kind", ["ldpc", "stereo"])
    def test_pad_pgm_roundtrip_is_inert(self, kind):
        """Padding a zoo graph to its bucket ceilings must not change the
        LBP trajectory on real edges (the serving tier pads every
        request; a generator whose padding leaks would break serving)."""
        pgm = WORKLOADS[kind](seed=1, **_SMALL[kind])
        e, v, s, re_, rv = bucket_shape(pgm, 2.0)
        padded = pad_pgm(pgm, n_edges=e, n_vertices=v, n_states=s,
                         n_real_edges=re_, n_real_vertices=rv)
        assert padded.log_psi_e.shape[0] >= pgm.log_psi_e.shape[0]
        engine = BPEngine(BPConfig(scheduler="lbp", eps=1e-4,
                                   max_rounds=400, history=False))
        a = engine.run(pgm, jax.random.key(0))
        b = engine.run(padded, jax.random.key(0))
        assert int(a.rounds) == int(b.rounds)
        nv, s0 = int(pgm.n_real_vertices), a.beliefs.shape[1]
        np.testing.assert_allclose(np.asarray(b.beliefs)[:nv, :s0],
                                   np.asarray(a.beliefs)[:nv], atol=1e-6)


def _exact_marginal_probs(n_vertices, edges, unary, pairwise, fn):
    margs = fn(n_vertices, edges, unary, pairwise)
    return [np.asarray(m, dtype=np.float64) for m in margs]


class TestLDPCConformance:
    """Max-product decoding of small codes against the exact oracles."""

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_maxprod_decode_matches_exact_map(self, seed):
        # n=8, dv=2, dc=4: 4 checks of 8 aux states; the joint space is
        # 2^8 * 8^4 ~ 1e6, inside brute force's budget.
        inst = ldpc_code(8, dv=2, dc=4, snr_db=3.0, seed=seed)
        engine = BPEngine(BPConfig(scheduler="lbp", backend="maxprod",
                                   eps=1e-5, max_rounds=600, history=False))
        res = engine.run(inst.pgm, jax.random.key(seed))
        decoded = np.asarray(
            map_assignment(inst.pgm, res.logm))[: inst.n_bits]
        nv, edges, unary, pairwise = inst.raw()
        bf = _exact_marginal_probs(nv, edges, unary, pairwise,
                                   brute_force_marginals)
        ve = _exact_marginal_probs(nv, edges, unary, pairwise, ve_marginals)
        for b, v in zip(bf, ve):            # the two oracles agree
            np.testing.assert_allclose(b, v, atol=1e-8)
        exact_bits = np.array([int(np.argmax(bf[i]))
                               for i in range(inst.n_bits)])
        np.testing.assert_array_equal(decoded, exact_bits)

    def test_decoding_beats_uncoded(self):
        # The benchmark acceptance in miniature: across a few words at
        # moderate SNR, max-product fixes channel errors.
        engine = BPEngine(BPConfig(scheduler="lbp", backend="maxprod",
                                   eps=1e-4, max_rounds=400, history=False))
        coded = uncoded = 0
        for w in range(3):
            inst = ldpc_code(48, snr_db=2.0, seed=1000 * w + 7)
            res = engine.run(inst.pgm, jax.random.key(w))
            decoded = np.asarray(map_assignment(inst.pgm, res.logm))
            coded += inst.coded_errors(decoded)
            uncoded += inst.uncoded_errors
        assert uncoded > 0                  # the channel actually erred
        assert coded < uncoded


class TestStereoConformance:
    """Every registered scheduler's sum-product marginals on a small
    stereo grid match variable elimination within loopy-BP tolerance."""

    @pytest.fixture(scope="class")
    def small_stereo(self):
        inst = stereo_mrf(3, 4, 3, seed=1)
        exact = _exact_marginal_probs(*inst.raw(), ve_marginals)
        return inst, exact

    @pytest.mark.parametrize("sched", list_schedulers())
    def test_marginals_match_ve(self, sched, small_stereo):
        inst, exact = small_stereo
        engine = BPEngine(BPConfig(scheduler=sched, eps=1e-6,
                                   max_rounds=4000, history=False))
        res = engine.run(inst.pgm, jax.random.key(0))
        assert bool(res.converged), f"{sched} did not converge"
        n = inst.height * inst.width
        b = np.asarray(beliefs(inst.pgm, res.logm))[:n, : inst.n_disp]
        b = np.exp(b - b.max(axis=1, keepdims=True))
        b /= b.sum(axis=1, keepdims=True)
        err = max(float(np.abs(b[i] - exact[i]).max()) for i in range(n))
        assert err < 2e-2, f"{sched}: max marginal error {err:.3e}"


class TestHeterogeneousStream:
    """The tentpole regression: the mixed zoo stream served online is
    bitwise identical per request to solo runs on identically padded
    graphs -- under every admission policy and every routing policy."""

    N = 9

    @pytest.fixture(scope="class")
    def zoo(self):
        stream = [p for _, p in zoo_stream(self.N, seed=0)]
        rng = jax.random.key(0)
        engine = BPEngine(BPConfig(scheduler="lbp", backend="maxprod",
                                   eps=1e-3, max_rounds=256, history=False))
        want = {}
        for rid, pgm in enumerate(stream):
            # Solo reference on the online pipeline's exact padded shape:
            # bucket_shape ceilings with static n_real_* overrides, and
            # the pipeline's fold_in(rng, rid) key.
            e, v, s, re_, rv = bucket_shape(pgm, 2.0)
            padded = pad_pgm(pgm, n_edges=e, n_vertices=v, n_states=s,
                             n_real_edges=re_, n_real_vertices=rv)
            want[rid] = engine.run(padded, jax.random.fold_in(rng, rid))
        return stream, rng, engine, want

    def _check(self, records, want):
        assert sorted(r.rid for r in records) == sorted(want)
        for rec in records:
            w = want[rec.rid]
            assert int(rec.result.rounds) == int(w.rounds)
            assert int(rec.result.updates) == int(w.updates)
            np.testing.assert_array_equal(np.asarray(rec.result.logm),
                                          np.asarray(w.logm))

    @pytest.mark.parametrize("policy", list_admission_policies())
    def test_each_admission_policy_bitwise_vs_solo(self, policy, zoo):
        stream, rng, engine, want = zoo
        rep = serve_async(engine, iter(stream), rng, admission=policy,
                          max_batch=3, chunk_rounds=32, prefetch=4, slots=2)
        self._check(rep.records, want)

    @pytest.mark.parametrize("routing", list_routing_policies())
    @pytest.mark.parametrize("steal", [False, True])
    def test_each_routing_policy_bitwise_vs_solo(self, routing, steal, zoo):
        stream, rng, engine, want = zoo
        engines = [BPEngine(engine.config) for _ in range(2)]
        rep = serve_routed(engines, iter(stream), rng, routing=routing,
                           steal=steal, max_batch=3, chunk_rounds=32,
                           prefetch=4, slots=2)
        self._check(rep.records, want)
