"""Scheduler frontier-selection semantics (paper SSIII-IV) + hypothesis
property tests on the RnBP dynamic-p controller.

``hypothesis`` is an optional test extra: without it the controller
property tests skip (via ``pytest.importorskip``) and the frontier
semantics tests still run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                       # degrade: property tests skip
    def given(*_a, **_k):
        return lambda f: f

    def settings(*_a, **_k):
        return lambda f: f

    class st:  # noqa: N801 - stand-in namespace, never executed
        integers = floats = staticmethod(lambda *a, **k: None)

from repro.core import LBP, RBP, RLX, RLXTree, RS, RnBP
from repro.core import messages as M
from repro.pgm import ising_grid


def _setup(n=8, c=2.5, seed=0):
    pgm = ising_grid(n, c, seed=seed)
    logm = M.init_messages(pgm)
    cand, r = M.ref_update(pgm, logm)
    return pgm, r


class TestFrontiers:
    def test_lbp_selects_all(self):
        pgm, r = _setup()
        f, _ = LBP().select(pgm, r, 1e-3, jax.random.key(0), (), jnp.int32(9))
        assert bool(jnp.all(f == pgm.edge_mask))

    def test_rbp_topk_size(self):
        pgm, r = _setup()
        p = 1 / 16
        sched = RBP(p=p)
        f, _ = sched.select(pgm, r, 1e-3, jax.random.key(0), (),
                            jnp.int32(9))
        k = int(round(p * pgm.n_real_edges))
        # threshold semantics admit ties: frontier >= k but not wildly more
        assert k <= int(f.sum()) <= 4 * k + 8
        # selected residuals dominate unselected ones
        rr = np.asarray(r)
        fm = np.asarray(f)
        em = np.asarray(pgm.edge_mask)
        if fm.any() and (~fm & em).any():
            assert rr[fm].min() >= rr[~fm & em].max() - 1e-6

    def test_rs_splash_is_connected_ball(self):
        pgm, r = _setup()
        sched = RS(p=0.05, h=2)
        f, _ = sched.select(pgm, r, 1e-3, jax.random.key(0), (),
                            jnp.int32(9))
        assert int(f.sum()) > 0
        # frontier edges form h-hop balls: both endpoints in the ball set
        src = np.asarray(pgm.edge_src)[np.asarray(f)]
        dst = np.asarray(pgm.edge_dst)[np.asarray(f)]
        ball = set(src) | set(dst)
        assert all(s in ball and d in ball for s, d in zip(src, dst))

    def test_rnbp_eps_filter(self):
        pgm, r = _setup()
        sched = RnBP(low_p=1.0, high_p=1.0)  # disable the random filter
        eps = float(np.quantile(np.asarray(r)[np.asarray(pgm.edge_mask)],
                                0.5))
        f, _ = sched.select(pgm, r, eps, jax.random.key(0),
                            sched.init(pgm), jnp.int32(10**9))
        rr, fm = np.asarray(r), np.asarray(f)
        assert fm.sum() > 0
        assert np.all(rr[fm] >= eps)           # filter 1 enforced
        em = np.asarray(pgm.edge_mask)
        assert not np.any(fm & ~em)            # padding never selected

    def test_rlx_per_queue_topk(self):
        pgm, r = _setup()
        q, p = 8, 1 / 16
        sched = RLX(queues=q, sample=1.0, p=p)  # sample=1: every queue kept
        f, _ = sched.select(pgm, r, 1e-3, jax.random.key(0), sched.init(pgm),
                            jnp.int32(9))
        rr = np.asarray(jnp.where(pgm.edge_mask, r, 0.0)).reshape(q, -1)
        fm = np.asarray(f).reshape(q, -1)
        em = np.asarray(pgm.edge_mask)
        assert not np.any(np.asarray(f) & ~em)  # padding never selected
        k = max(1, round(p * pgm.n_real_edges / q))
        for qi in range(q):
            # threshold semantics per queue: >= k selected (ties), and the
            # selected residuals dominate this queue's unselected ones.
            assert fm[qi].sum() >= min(k, (rr[qi] > 0).sum())
            if fm[qi].any() and (~fm[qi]).any():
                assert rr[qi][fm[qi]].min() >= rr[qi][~fm[qi]].max() - 1e-6

    def test_rlx_sampling_is_monotone_and_never_empty(self):
        pgm, r = _setup()
        rng = jax.random.key(7)
        full, _ = RLX(sample=1.0).select(pgm, r, 1e-3, rng, (), jnp.int32(9))
        half, _ = RLX(sample=0.5).select(pgm, r, 1e-3, rng, (), jnp.int32(9))
        tiny, _ = RLX(sample=1e-6).select(pgm, r, 1e-3, rng, (), jnp.int32(9))
        # same rng => same uniform draws => kept-queue sets nest
        assert not np.any(np.asarray(half) & ~np.asarray(full))
        assert not np.any(np.asarray(tiny) & ~np.asarray(half))
        # the queue holding the max residual is always kept: the globally
        # hottest edge is in the frontier at any sample rate (no livelock)
        hot = int(np.argmax(np.asarray(jnp.where(pgm.edge_mask, r, 0.0))))
        for f in (full, half, tiny):
            assert int(np.asarray(f).sum()) > 0
            assert bool(np.asarray(tiny)[hot])

    def test_rlxtree_queues_are_dst_contiguous(self):
        pgm, r = _setup()
        sched = RLXTree(queues=8, sample=1.0, p=1 / 16)
        order = np.asarray(sched.init(pgm))
        em = np.asarray(pgm.edge_mask)
        dst_sorted = np.asarray(pgm.edge_dst)[order]
        n_real = int(em.sum())
        # state perm puts real edges first, in nondecreasing dst order:
        # contiguous queues == contiguous destination neighborhoods
        assert np.all(em[order][:n_real])
        assert np.all(np.diff(dst_sorted[:n_real]) >= 0)
        f, state = sched.select(pgm, r, 1e-3, jax.random.key(0),
                                sched.init(pgm), jnp.int32(9))
        assert np.array_equal(np.asarray(state), order)  # perm is carried
        assert int(np.asarray(f).sum()) > 0
        assert not np.any(np.asarray(f) & ~em)


class TestRnBPController:
    # class-scoped: a function-scoped autouse fixture would trip
    # Hypothesis's function_scoped_fixture health check when it IS installed
    @pytest.fixture(autouse=True, scope="class")
    def _require_hypothesis(self):
        pytest.importorskip("hypothesis")

    @settings(max_examples=30, deadline=None)
    @given(old=st.integers(1, 10**6), new=st.integers(0, 10**6))
    def test_dynamic_p_rule(self, old, new):
        """EdgeRatio > 0.9 -> LowP (convergence mode), else HighP."""
        pgm, r = _setup(6)
        sched = RnBP(low_p=0.25, high_p=1.0, ratio_threshold=0.9)
        f, state = sched.select(pgm, r, 0.0, jax.random.key(1),
                                jnp.float32(old), jnp.int32(new))
        assert float(state) == float(new)      # carry = new count
        ratio = new / max(old, 1)
        em = np.asarray(pgm.edge_mask)
        frac = np.asarray(f)[em].mean()
        if ratio > 0.9:
            assert frac < 0.6                  # ~low_p of candidates
        else:
            assert frac > 0.8                  # ~high_p == full frontier

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_random_filter_unbiased(self, seed):
        pgm, r = _setup(10)
        sched = RnBP(low_p=0.5, high_p=0.5, ratio_threshold=-1.0)
        f, _ = sched.select(pgm, r, 0.0, jax.random.key(seed),
                            sched.init(pgm), jnp.int32(0))
        em = np.asarray(pgm.edge_mask)
        frac = np.asarray(f)[em].mean()
        assert 0.35 < frac < 0.65              # Bernoulli(0.5) concentration
