"""Pallas kernel validation: interpret-mode vs pure-jnp oracle across
shape/dtype sweeps + hypothesis property tests on kernel semantics.

``hypothesis`` is an optional test extra: without it the property-test
class skips (via ``pytest.importorskip``) and the oracle tests still run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                       # degrade: property tests skip
    def given(*_a, **_k):
        return lambda f: f

    def settings(*_a, **_k):
        return lambda f: f

    class st:  # noqa: N801 - stand-in namespace, never executed
        integers = floats = staticmethod(lambda *a, **k: None)

from repro.core import messages as M
from repro.core.graph import NEG_INF
from repro.kernels.message_update import fused_update_t, pick_block_edges
from repro.kernels.ops import make_pallas_update, pallas_update
from repro.kernels.ref import fused_update_t_ref
from repro.pgm import ising_grid, protein_like_graph


def _rand_operands(rng, s, e, dtype=jnp.float32):
    logpsi = rng.standard_normal((s, s, e)).astype(np.float32)
    pre = rng.standard_normal((s, e)).astype(np.float32)
    # valid-state masks: at least 1 valid state per edge
    nvalid = rng.integers(1, s + 1, size=e)
    dmask = (np.arange(s)[:, None] < nvalid[None, :])
    logm = np.where(dmask, rng.standard_normal((s, e)), NEG_INF)
    return (jnp.asarray(logpsi, dtype), jnp.asarray(pre, dtype),
            jnp.asarray(logm, dtype), jnp.asarray(dmask))


SHAPES = [(2, 128), (2, 256), (3, 128), (8, 384), (17, 128), (51, 256),
          (81, 128), (96, 128)]


class TestKernelVsOracle:
    @pytest.mark.parametrize("s,e", SHAPES)
    def test_allclose_f32(self, s, e):
        rng = np.random.default_rng(s * 1000 + e)
        ops = _rand_operands(rng, s, e)
        new_k, r_k = fused_update_t(*ops, interpret=True)
        new_r, r_r = fused_update_t_ref(*ops)
        dmask = np.asarray(ops[3])
        np.testing.assert_allclose(
            np.where(dmask, np.asarray(new_k), 0.0),
            np.where(dmask, np.asarray(new_r), 0.0), atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(r_k), np.asarray(r_r),
                                   atol=1e-5, rtol=1e-5)

    @pytest.mark.parametrize("s,e", [(2, 128), (8, 256)])
    def test_allclose_bf16_operands(self, s, e):
        """bf16 messages (serving-precision BP) still match the oracle."""
        rng = np.random.default_rng(7)
        ops = _rand_operands(rng, s, e, dtype=jnp.bfloat16)
        new_k, r_k = fused_update_t(*ops, interpret=True)
        new_r, r_r = fused_update_t_ref(*ops)
        dmask = np.asarray(ops[3])
        np.testing.assert_allclose(
            np.where(dmask, np.asarray(new_k, np.float32), 0.0),
            np.where(dmask, np.asarray(new_r, np.float32), 0.0),
            atol=3e-2, rtol=3e-2)

    def test_unpadded_edge_count(self):
        """E not a multiple of the block: internal padding must be inert."""
        rng = np.random.default_rng(11)
        ops = _rand_operands(rng, 4, 130)  # 130 not a lane multiple
        new_k, r_k = fused_update_t(*ops, interpret=True)
        new_r, r_r = fused_update_t_ref(*ops)
        assert new_k.shape == (4, 130)
        np.testing.assert_allclose(np.asarray(r_k), np.asarray(r_r),
                                   atol=1e-5)

    def test_block_picker_vmem_budget(self):
        for s in [2, 8, 32, 81, 96]:
            blk = pick_block_edges(s)
            assert blk % 128 == 0 and blk >= 128
            ws = (s * s + 4 * s + 2) * blk * 4
            assert ws <= 4 * 1024 * 1024 * 2  # within 2x of budget


class TestKernelInBP:
    def test_pallas_update_equals_ref_update(self):
        for make in [lambda: ising_grid(12, 2.5, seed=2),
                     lambda: protein_like_graph(50, seed=2)]:
            pgm = make()
            logm = M.init_messages(pgm)
            for _ in range(2):
                cand, _ = M.ref_update(pgm, logm)
                logm = M.apply_frontier(logm, cand, pgm.edge_mask)
            c_r, r_r = M.ref_update(pgm, logm)
            c_k, r_k = pallas_update(pgm, logm, interpret=True)
            mask = np.asarray(pgm.state_mask[pgm.edge_dst])
            np.testing.assert_allclose(
                np.where(mask, np.asarray(c_k), 0.0),
                np.where(mask, np.asarray(c_r), 0.0), atol=1e-5)
            np.testing.assert_allclose(np.asarray(r_k), np.asarray(r_r),
                                       atol=1e-5)

    def test_e2e_run_bp_with_kernel(self):
        """Kernel-backed BP reaches the reference fixed point. Trajectories
        may differ by a few rounds: the fused kernel's normalize/residual
        reassociates reductions, and at eps=1e-5 ulp-level differences can
        shift residual-threshold crossings (pre-existing; masked while this
        module failed at collection)."""
        from repro.core import RnBP, run_bp
        pgm = ising_grid(10, 2.5, seed=3)
        r_ref = run_bp(pgm, RnBP(low_p=0.7), jax.random.key(0), eps=1e-5)
        r_k = run_bp(pgm, RnBP(low_p=0.7), jax.random.key(0), eps=1e-5,
                     update_fn=make_pallas_update(True))
        assert bool(r_ref.converged) and bool(r_k.converged)
        assert abs(int(r_ref.rounds) - int(r_k.rounds)) \
            <= max(10, int(r_ref.rounds) // 10)
        # both stop when every residual < eps; beliefs sum ~degree messages,
        # so the fixed points agree to ~degree * eps
        np.testing.assert_allclose(np.asarray(r_ref.beliefs),
                                   np.asarray(r_k.beliefs), atol=1e-4)


class TestKernelProperties:
    """Hypothesis property tests on the fused-update contract."""

    # class-scoped: a function-scoped autouse fixture would trip
    # Hypothesis's function_scoped_fixture health check when it IS installed
    @pytest.fixture(autouse=True, scope="class")
    def _require_hypothesis(self):
        pytest.importorskip("hypothesis")

    @settings(max_examples=25, deadline=None)
    @given(s=st.integers(2, 12), seed=st.integers(0, 2**16),
           scale=st.floats(0.1, 20.0))
    def test_output_normalized_and_residual_nonneg(self, s, seed, scale):
        rng = np.random.default_rng(seed)
        e = 128
        logpsi = (scale * rng.standard_normal((s, s, e))).astype(np.float32)
        pre = (scale * rng.standard_normal((s, e))).astype(np.float32)
        nvalid = rng.integers(1, s + 1, size=e)
        dmask = (np.arange(s)[:, None] < nvalid[None, :])
        logm = np.where(dmask, rng.standard_normal((s, e)), NEG_INF)
        new, r = fused_update_t(jnp.asarray(logpsi), jnp.asarray(pre),
                                jnp.asarray(logm.astype(np.float32)),
                                jnp.asarray(dmask), interpret=True)
        new = np.asarray(new, np.float64)
        # (1) normalized over valid states (f32 LSE at scale 20 -> ~1e-3)
        z = np.sum(np.where(dmask, np.exp(new), 0.0), axis=0)
        np.testing.assert_allclose(z, 1.0, atol=2e-3)
        # (2) invalid states carry the log(0) sentinel (f32-rounded)
        assert np.all(new[~dmask] == np.float64(np.float32(NEG_INF)))
        # (3) residuals non-negative and finite
        r = np.asarray(r)
        assert np.all(r >= 0) and np.all(np.isfinite(r))

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_idempotent_at_fixed_point(self, seed):
        """Feeding back the kernel's own output as messages yields residual
        == 0 only if inputs unchanged -- here: residual of (new vs new) = 0."""
        rng = np.random.default_rng(seed)
        s, e = 4, 128
        ops = _rand_operands(rng, s, e)
        new, _ = fused_update_t(*ops, interpret=True)
        _, r2 = fused_update_t(ops[0], ops[1], new, ops[3], interpret=True)
        r_self = np.asarray(fused_update_t(ops[0], ops[1], new, ops[3],
                                           interpret=True)[0])
        np.testing.assert_allclose(np.asarray(r2),
                                   np.max(np.where(np.asarray(ops[3]),
                                                   np.abs(r_self
                                                          - np.asarray(new)),
                                                   0.0), axis=0), atol=1e-5)
