"""Max-product (MAP) BP variant: exact on trees, scheduler-agnostic
(validates the paper's SSV claim that RnBP composes with BP variants)."""

import itertools

import jax
import numpy as np
import pytest

from repro.core import LBP, RnBP, run_bp
from repro.core import messages as M
from repro.pgm import chain_graph, small_ising


def _brute_force_map(n, edges, unary, pairwise):
    best, best_score = None, -np.inf
    for assign in itertools.product(*[range(len(u)) for u in unary]):
        s = sum(np.log(unary[v][assign[v]]) for v in range(n))
        s += sum(np.log(pairwise[k][assign[i], assign[j]])
                 for k, (i, j) in enumerate(edges))
        if s > best_score:
            best_score, best = s, assign
    return np.array(best), best_score


class TestMaxProduct:
    @pytest.mark.parametrize("sched", [LBP(), RnBP(low_p=0.7)],
                             ids=["LBP", "RnBP"])
    def test_map_exact_on_small_grid(self, sched):
        pgm, nv, edges, unary, pairwise = small_ising(3, 2.0, seed=5)
        res = run_bp(pgm, sched, jax.random.key(0), eps=1e-6,
                     max_rounds=3000, update_fn=M.max_product_update)
        assert bool(res.converged)
        got = np.asarray(M.map_assignment(pgm, res.logm))[:nv]
        want, want_score = _brute_force_map(nv, edges, unary, pairwise)
        # compare SCORES (ties in argmax are legitimate)
        score = sum(np.log(unary[v][got[v]]) for v in range(nv))
        score += sum(np.log(pairwise[k][got[i], got[j]])
                     for k, (i, j) in enumerate(edges))
        np.testing.assert_allclose(score, want_score, rtol=1e-5)

    def test_map_exact_on_chain(self):
        pgm = chain_graph(30, C=4.0, seed=2)
        res = run_bp(pgm, RnBP(low_p=0.7), jax.random.key(1), eps=1e-6,
                     max_rounds=3000, update_fn=M.max_product_update)
        assert bool(res.converged)
        # chain MAP via Viterbi (exact DP)
        rng = np.random.default_rng(2)
        unary = [rng.uniform(1e-3, 1.0, size=2) for _ in range(30)]
        lam = rng.uniform(-0.5, 0.5, size=29)
        pair = [np.log(np.array([[np.exp(l * 4), np.exp(-l * 4)],
                                 [np.exp(-l * 4), np.exp(l * 4)]]))
                for l in lam]
        lu = [np.log(u) for u in unary]
        dp = lu[0].copy()
        back = []
        for t in range(1, 30):
            cand = dp[:, None] + pair[t - 1]
            back.append(np.argmax(cand, axis=0))
            dp = np.max(cand, axis=0) + lu[t]
        path = [int(np.argmax(dp))]
        for b in reversed(back):
            path.append(int(b[path[-1]]))
        viterbi = np.array(path[::-1])
        got = np.asarray(M.map_assignment(pgm, res.logm))[:30]
        np.testing.assert_array_equal(got, viterbi)

    def test_messages_max_normalized(self):
        pgm, *_ = small_ising(4, 2.5, seed=1)
        res = run_bp(pgm, LBP(), jax.random.key(0), eps=1e-5,
                     max_rounds=2000, update_fn=M.max_product_update)
        logm = np.asarray(res.logm)
        mask = np.asarray(pgm.state_mask[pgm.edge_dst])
        em = np.asarray(pgm.edge_mask)
        mx = np.max(np.where(mask, logm, -np.inf), axis=1)
        np.testing.assert_allclose(mx[em], 0.0, atol=1e-4)
