"""SLA-aware serving conformance suite: deadline admission determinism,
eviction correctness, slot-packing parity, the learned effort predictor,
and no-starvation under sustained overload.

Every timing assertion runs on an injected :class:`SweepClock` (virtual
time = device sweeps), never on wall time -- the whole deadline/eviction
story is a pure function of scheduling decisions, so these pins hold
bit-for-bit on any machine. The companion invariant from the serving
suite carries over: a request's trajectory depends only on its padded
shape and ``fold_in(rng, rid)``, so eviction of *other* requests, slot
packing, and admission order can never change a surviving result bit.
"""

import json
import threading
import time

import jax
import numpy as np
import pytest

from repro.core import (ADMISSION_POLICIES, BPConfig, BPEngine,
                        DeadlineAdmission, RidgeEffort, RoundsHistory,
                        ServingPipeline, SweepClock, serve_async)
from repro.core.serving import (AsyncServeResult, AsyncServeStats,
                                RequestRecord, _Group, _Staged)
from repro.pgm import chain_graph, ising_grid
from repro.serve import serve_routed
from repro.serve.router import RouterResult, RouterStats

CFG = BPConfig(scheduler="lbp", eps=1e-5, max_rounds=160, history=False)
#: one virtual second per device sweep; slots=1 keeps chunk accounting
#: exactly sequential so expected sync times are computable by hand.
KW = dict(slots=1, max_batch=2, chunk_rounds=16, prefetch=None)


@pytest.fixture(scope="module")
def engine():
    return BPEngine(CFG)


def _fast(seed=0):
    # ~15-25 LBP rounds to eps=1e-5 (measured, deterministic).
    return ising_grid(6, 1.5, seed=seed)


def _impossible():
    # Never converges within max_rounds=160 (measured, deterministic).
    return ising_grid(6, 3.5, seed=0)


def _assert_bitwise(got, want):
    assert int(got.rounds) == int(want.rounds)
    assert int(got.updates) == int(want.updates)
    np.testing.assert_array_equal(np.asarray(got.logm), np.asarray(want.logm))


def _timeline(rep):
    return [(r.rid, r.status, r.t_enqueue, r.t_admit, r.t_done,
             int(r.result.rounds)) for r in rep.records]


class TestSweepClock:
    """Deterministic virtual time: the fixed-clock injection every other
    test in this file relies on."""

    def test_virtual_time_arithmetic(self):
        clock = SweepClock()
        assert clock() == 0.0
        clock.on_chunk(64)
        assert clock() == 64.0
        clock.advance(5.5)
        assert clock() == 69.5

    def test_tau_scales_sweeps(self):
        clock = SweepClock(tau=0.25)
        clock.on_chunk(16)
        assert clock() == 4.0

    def test_tau_validation(self):
        with pytest.raises(ValueError):
            SweepClock(tau=0.0)
        with pytest.raises(ValueError):
            SweepClock(tau=-1.0)


class TestDeadlineDeterminism:
    """Acceptance: under an injected SweepClock the full serving timeline
    (admission order, sync times, evictions) is run-to-run identical --
    no wall-clock leak anywhere in the deadline path."""

    def _stream(self):
        return [(0, _impossible(), 40.0), (1, _fast(0), None),
                (2, _fast(1), 500.0), (3, _fast(2), 500.0)]

    def test_run_to_run_identical(self, engine):
        runs = []
        for _ in range(2):
            clock = SweepClock()
            rep = serve_async(engine, iter(self._stream()),
                              jax.random.key(0), admission="deadline",
                              clock=clock, **KW)
            runs.append((rep, _timeline(rep), clock.t,
                         list(rep.stats.eviction_log)))
        (a, tl_a, t_a, ev_a), (b, tl_b, t_b, ev_b) = runs
        assert tl_a == tl_b
        assert t_a == t_b
        assert ev_a == ev_b
        assert a.stats.evictions == b.stats.evictions
        for ra, rb in zip(a.records, b.records):
            _assert_bitwise(ra.result, rb.result)

    def test_wall_time_sleeps_do_not_move_virtual_time(self, engine):
        def slow_stream():
            for item in self._stream():
                time.sleep(0.01)        # wall time must be invisible
                yield item
        want = serve_async(engine, iter(self._stream()), jax.random.key(0),
                           admission="deadline", clock=SweepClock(), **KW)
        got = serve_async(engine, slow_stream(), jax.random.key(0),
                          admission="deadline", clock=SweepClock(), **KW)
        assert _timeline(got) == _timeline(want)


class TestEvictionCorrectness:
    """Evicted requests surface with partial beliefs and correct sweep
    accounting; survivors are bitwise-identical to a fifo run."""

    def test_midflight_eviction_partial_result_and_accounting(self, engine):
        # Width-2 bucket: 32 virtual s per chunk sync. The impossible
        # graph's deadline (40) falls between sync 1 (t=32) and sync 2
        # (t=64), so it is evicted at t=64 with 32 rounds on the clock.
        stream = [(0, _impossible(), 40.0), (1, _fast(0), None)]
        rep = serve_async(engine, iter(stream), jax.random.key(0),
                          admission="deadline", clock=SweepClock(), **KW)
        by_rid = {r.rid: r for r in rep.records}
        ev = by_rid[0]
        assert ev.status == "evicted" and ev.evicted
        assert not ev.within_slo
        assert not bool(ev.result.converged)
        rounds = int(ev.result.rounds)
        assert rounds == 32
        assert ev.t_done == 64.0
        # partial beliefs, not a silent drop: finite and normalized
        b = np.asarray(ev.result.beliefs)
        real = np.isfinite(b).any(axis=-1)
        mass = np.exp(b[real]).sum(axis=-1)
        np.testing.assert_allclose(mass, 1.0, rtol=1e-5)
        assert rep.stats.evictions == 1
        assert rep.stats.evicted_sweeps == rounds
        assert [rid for _, rid in rep.stats.eviction_log] == [0]
        ok = by_rid[1]
        assert ok.status == "completed" and ok.within_slo

    def test_survivors_bitwise_match_fifo_run(self, engine):
        graphs = [(0, _impossible(), 30.0), (1, _fast(0), None),
                  (2, _fast(1), 400.0), (3, chain_graph(30, seed=2), None),
                  (4, _fast(2), None)]
        dl = serve_async(engine, iter(graphs), jax.random.key(7),
                         admission="deadline", clock=SweepClock(), **KW)
        fifo = serve_async(engine,
                           iter([(rid, pgm) for rid, pgm, _ in graphs]),
                           jax.random.key(7), admission="fifo", **KW)
        fifo_by_rid = {r.rid: r.result for r in fifo.records}
        survivors = [r for r in dl.records if not r.evicted]
        assert {r.rid for r in survivors} == {1, 2, 3, 4}
        for rec in survivors:
            _assert_bitwise(rec.result, fifo_by_rid[rec.rid])

    def test_staged_eviction_prior_beliefs_zero_service(self, engine):
        # One lane: the impossible head occupies it for 160 rounds of
        # virtual time; the deadlined request expires while still staged
        # and must come back with prior beliefs and zero service time.
        def stream():
            yield (0, _impossible(), None)
            yield (1, _fast(0), 10.0)
        rep = serve_async(engine, stream(), jax.random.key(0),
                          admission="deadline", clock=SweepClock(),
                          slots=1, max_batch=1, chunk_rounds=16, prefetch=1)
        by_rid = {r.rid: r for r in rep.records}
        ev = by_rid[1]
        assert ev.status == "evicted"
        assert int(ev.result.rounds) == 0
        assert ev.t_admit == ev.t_done        # never entered a bucket
        assert ev.service_s == 0.0
        assert not bool(ev.result.converged)
        b = np.asarray(ev.result.beliefs)
        real = np.isfinite(b).any(axis=-1)
        np.testing.assert_allclose(np.exp(b[real]).sum(axis=-1), 1.0,
                                   rtol=1e-5)
        assert rep.stats.evictions == 1
        head = by_rid[0]
        assert head.status == "completed"     # no SLO: never given up on
        assert not bool(head.result.converged)

    def test_evict_false_never_gives_up(self, engine):
        stream = [(0, _impossible(), 40.0), (1, _fast(0), None)]
        rep = serve_async(engine, iter(stream), jax.random.key(0),
                          admission="deadline",
                          admission_kwargs={"evict": False},
                          clock=SweepClock(), **KW)
        assert rep.stats.evictions == 0
        by_rid = {r.rid: r for r in rep.records}
        assert by_rid[0].status == "completed"
        assert not by_rid[0].within_slo       # missed, but served


class TestSlotPackingParity:
    """The pick_many hook: its default single-pick path is exactly
    pick_group, and packing is bitwise-invisible to results -- for every
    registered policy."""

    def _fake_groups(self):
        a = _Group((64, 32, 2, 4, 4))
        a.queue.extend([
            _Staged(rid=0, elem=None, key=None, t_enqueue=1.0, score=0.3,
                    slo=900.0),
            _Staged(rid=1, elem=None, key=None, t_enqueue=2.0, score=0.1,
                    slo=50.0)])
        b = _Group((128, 64, 2, 8, 8))
        b.queue.extend([
            _Staged(rid=2, elem=None, key=None, t_enqueue=0.5, score=0.7,
                    slo=200.0)])
        return [a, b]

    def test_pick_many_free1_equals_pick_group_all_policies(self):
        for name, cls in sorted(ADMISSION_POLICIES.items()):
            policy = cls()
            if name == "windowed":
                # windowed consults the pipeline for exhaustion/targets; an
                # exhausted stub makes every group immediately ready.
                policy.pipeline = type("P", (), {"_exhausted": True,
                                                 "max_batch": 2,
                                                 "_groups": {}})()
            groups = self._fake_groups()
            want = policy.pick_group(groups, now=3.0)
            got = policy.pick_many(groups, now=3.0, free=1)
            assert got == [want], f"policy {name!r} diverges from pick_group"

    def test_deadline_pick_many_packs_by_urgency(self):
        policy = DeadlineAdmission()
        groups = self._fake_groups()
        # group a's head-of-queue urgency (slo 50 at t_enqueue 2) beats
        # group b's (slo 200): packing returns both, most urgent first.
        got = policy.pick_many(groups, now=3.0, free=2)
        assert got == [groups[0], groups[1]]
        assert policy.pick_many(groups, now=3.0, free=1) == [groups[0]]

    @pytest.mark.parametrize("name", sorted(ADMISSION_POLICIES))
    def test_packing_is_bitwise_invisible(self, engine, name):
        # Mixed shape families so multiple groups coexist and slots=3
        # actually packs; trajectory invariance demands identical results.
        stream = [(0, _fast(0), None), (1, chain_graph(30, seed=1), None),
                  (2, _fast(1), None), (3, chain_graph(34, seed=2), None),
                  (4, ising_grid(7, 1.5, seed=3), None)]
        kw = dict(max_batch=2, chunk_rounds=16, prefetch=None)
        one = serve_async(engine, iter(stream), jax.random.key(5),
                          admission=name, clock=SweepClock(), slots=1, **kw)
        packed = serve_async(engine, iter(stream), jax.random.key(5),
                             admission=name, clock=SweepClock(), slots=3,
                             **kw)
        a = {r.rid: r.result for r in one.records}
        b = {r.rid: r.result for r in packed.records}
        assert sorted(a) == sorted(b) == [0, 1, 2, 3, 4]
        for rid in a:
            _assert_bitwise(b[rid], a[rid])


class TestLearnedEffort:
    """The ridge effort predictor behind RoundsHistory.expect: beats the
    nearest-neighbor table it replaced, round-trips exactly, and cold
    starts safely."""

    KINDS = [(64, 32, 2, 4, 4), (256, 128, 2, 8, 8), (1024, 512, 2, 16, 16)]

    @staticmethod
    def _rounds(kind, score):
        # Ground truth linear in the ridge features: learnable exactly.
        return 5.0 + 20.0 * score + 3.0 * np.log1p(kind[0])

    def _observe_all(self, hist):
        for kind in self.KINDS[:2]:
            for score in (0.05, 0.2, 0.4, 0.6, 0.8):
                hist.observe(kind, score, self._rounds(kind, score))

    def test_ridge_beats_nearest_mae(self):
        ridge = RoundsHistory(predictor="ridge", l2=1e-3)
        nearest = RoundsHistory(predictor="nearest")
        self._observe_all(ridge)
        self._observe_all(nearest)
        # Held-out queries: unseen scores on seen kinds, plus a kind
        # nearest has never recorded (it can only fall back to default).
        queries = [(self.KINDS[0], 0.3), (self.KINDS[0], 0.7),
                   (self.KINDS[1], 0.1), (self.KINDS[1], 0.5),
                   (self.KINDS[2], 0.25), (self.KINDS[2], 0.65)]
        fallback = 30.0

        def mae(hist):
            errs = [abs(hist.expect(k, s, default=fallback)
                        - self._rounds(k, s)) for k, s in queries]
            return sum(errs) / len(errs)

        assert mae(ridge) < mae(nearest)
        assert mae(ridge) < 1.0           # linear truth: near-exact fit

    def test_ridge_cold_start_returns_none(self):
        model = RidgeEffort()
        x = RidgeEffort.features((64, 32, 2, 4, 4), 0.5)
        assert model.predict(x) is None
        model.fit_one(x, 10.0)
        assert model.predict(x) is None   # one point cannot anchor a slope
        model.fit_one(RidgeEffort.features((64, 32, 2, 4, 4), 0.9), 20.0)
        assert model.predict(x) is not None
        with pytest.raises(ValueError):
            RidgeEffort(l2=0.0)

    def test_expect_default_and_prior_seeding(self):
        cold = RoundsHistory()
        assert cold.expect((1, 2, 3), 0.5) is None
        assert cold.expect((1, 2, 3), 0.5, default=7.0) == 7.0
        assert cold.mean() is None
        assert cold.mean(default=3.0) == 3.0
        seeded = RoundsHistory(prior=42.0)
        assert seeded.expect((1, 2, 3), 0.5) == 42.0
        assert seeded.expect((1, 2, 3), 0.5, default=7.0) == 42.0
        assert seeded.mean((9, 9, 9)) == 42.0
        seeded.observe((1, 2, 3), 0.5, 11.0)
        seeded.observe((1, 2, 3), 0.6, 13.0)
        assert seeded.mean((1, 2, 3)) == pytest.approx(12.0)
        # an unseen kind now prefers the global mean over the prior
        assert seeded.mean((9, 9, 9)) == pytest.approx(12.0)

    def test_serialization_roundtrip_identical_predictions(self):
        hist = RoundsHistory(capacity=8, predictor="ridge", prior=17.0)
        self._observe_all(hist)
        hist.observe(self.KINDS[2], 0.33, 44.0, extra=(1.5, 0.2))
        blob = json.dumps(hist.to_dict())      # JSON-safe end to end
        back = RoundsHistory.from_dict(json.loads(blob))
        assert back.capacity == 8 and back.prior == 17.0
        assert back.predictor == "ridge"
        for kind in self.KINDS:
            for score in (0.0, 0.15, 0.5, 0.95):
                assert back.expect(kind, score) == hist.expect(kind, score)
            assert back.mean(kind) == hist.mean(kind)

    def test_ridge_model_roundtrip_exact(self):
        model = RidgeEffort(l2=0.5)
        rng = np.random.default_rng(0)
        for _ in range(10):
            model.fit_one(rng.normal(size=RidgeEffort.DIM),
                          float(rng.uniform(1, 100)))
        back = RidgeEffort.from_dict(model.to_dict())
        assert back.n_observations == model.n_observations
        x = rng.normal(size=RidgeEffort.DIM)
        assert back.predict(x) == model.predict(x)


class TestNoStarvation:
    """A generous-deadline request cannot be passed over forever by a
    stream of urgent arrivals: the aging counter force-admits it."""

    def test_aging_force_admits_passed_over_head(self):
        policy = DeadlineAdmission(aging=2)
        group = _Group((64, 32, 2, 4, 4))
        group.queue.append(_Staged(rid=0, elem=None, key=None,
                                   t_enqueue=0.0, slo=10_000.0))
        admitted = []
        for i in range(1, 6):
            group.queue.append(_Staged(rid=100 + i, elem=None, key=None,
                                       t_enqueue=float(i), slo=5.0))
            admitted += [s.rid for s in policy.take(group, 1)]
            if 0 in admitted:
                break
        # skipped at most `aging` times, then force-admitted
        assert 0 in admitted
        assert admitted.index(0) <= policy.aging

    def test_sustained_overload_serves_everyone(self, engine):
        # Every request is feasible; the generous one arrives first and
        # keeps losing the slack race -- it must still complete.
        stream = [(0, _fast(0), 10_000.0)] + \
                 [(i, _fast(i), 500.0) for i in range(1, 7)]
        rep = serve_async(engine, iter(stream), jax.random.key(0),
                          admission="deadline",
                          admission_kwargs={"aging": 2},
                          clock=SweepClock(), **KW)
        assert sorted(r.rid for r in rep.records) == list(range(7))
        assert rep.stats.evictions == 0
        assert all(r.status == "completed" for r in rep.records)
        assert {r.rid for r in rep.records if r.within_slo} >= {0}


class TestLifecycleAndRouting:
    """Teardown under in-flight eviction, and the router tier merging
    evicted records with replica attribution."""

    def _wait_threads(self, baseline, timeout=10.0):
        deadline = time.time() + timeout
        while threading.active_count() > baseline and time.time() < deadline:
            time.sleep(0.02)
        return threading.active_count()

    def test_close_under_inflight_eviction(self, engine):
        baseline = threading.active_count()
        stream = [(0, _impossible(), 40.0), (1, _fast(0), None),
                  (2, _fast(1), None)]
        pipe = ServingPipeline(engine, jax.random.key(0),
                               admission="deadline", clock=SweepClock(),
                               ingest_threads=1, **KW)
        gen = pipe.serve(iter(stream))
        first = next(gen)                  # mid-flight, work still resident
        assert first.rid in {0, 1, 2}
        gen.close()
        pipe.close()
        assert self._wait_threads(baseline) <= baseline
        with pytest.raises(ValueError):
            list(pipe.serve(iter([])))
        pipe.close()                       # idempotent

    def test_serve_routed_merges_evicted_with_attribution(self):
        clock = SweepClock()
        stream = [(0, _impossible(), 80.0),
                  (1, ising_grid(6, 3.5, seed=2), 80.0),
                  (2, _fast(0), None), (3, _fast(1), None),
                  (4, _fast(2), None), (5, _fast(3), None)]
        res = serve_routed(CFG, iter(stream), jax.random.key(0),
                           replicas=2, routing="round_robin", steal=False,
                           admission="deadline", clock=clock, slots=1,
                           max_batch=2, chunk_rounds=16, prefetch=4)
        assert sorted(r.rid for r in res.records) == list(range(6))
        evicted = [r for r in res.records if r.evicted]
        assert {r.rid for r in evicted} == {0, 1}
        for rec in evicted:
            assert rec.status == "evicted" and not rec.within_slo
            assert rec.replica == rec.rid % 2      # round_robin attribution
            assert not bool(rec.result.converged)
            assert np.isfinite(np.asarray(rec.result.beliefs)).any()
        assert sum(s.evictions for s in res.replica_stats) == 2
        completed = [r for r in res.records if not r.evicted]
        assert all(r.within_slo for r in completed)

    def test_router_percentiles_status_filter(self):
        def rec(rid, t_done, status="completed"):
            return RequestRecord(rid=rid, result=None, t_enqueue=0.0,
                                 t_admit=0.1, t_done=t_done, status=status)
        from repro.serve.replica import RoutedRecord
        records = [
            RoutedRecord(replica=0, kind=(1,), stolen=False, t_route=0.0,
                         record=rec(0, 2.0)),
            RoutedRecord(replica=1, kind=(1,), stolen=False, t_route=0.0,
                         record=rec(1, 0.25, status="evicted"))]
        res = RouterResult(records=records,
                           stats=RouterStats(policy="round_robin",
                                             steal=False, routed=[1, 1]),
                           replica_stats=[])
        assert res.latency_percentiles((50,))["p50"] == \
            pytest.approx(1125.0)          # mixed: the eviction lies
        assert res.latency_percentiles(
            (50,), status="completed")["p50"] == pytest.approx(2000.0)
        assert res.latency_percentiles(
            (50,), status="evicted")["p50"] == pytest.approx(250.0)
        assert not np.isnan(res.latency_percentiles(
            (50,), field="service", status=None)["p50"])
        with pytest.raises(ValueError):
            res.latency_percentiles(status="bogus")

    def test_async_percentiles_status_filter(self):
        recs = [RequestRecord(rid=0, result=None, t_enqueue=0.0,
                              t_admit=0.5, t_done=1.0),
                RequestRecord(rid=1, result=None, t_enqueue=0.0,
                              t_admit=0.05, t_done=0.1, status="evicted")]
        rep = AsyncServeResult(records=recs, stats=AsyncServeStats())
        assert rep.latency_percentiles(
            (50,), status="completed")["p50"] == pytest.approx(1000.0)
        assert rep.latency_percentiles(
            (50,), status="evicted")["p50"] == pytest.approx(100.0)
        assert not np.isnan(rep.latency_percentiles(
            (50,), status="evicted", field="admission")["p50"])
        with pytest.raises(ValueError):
            rep.latency_percentiles(status="nope")


class TestPropertySweeps:
    """Hypothesis property sweeps (each skips when hypothesis is absent --
    per-test importorskip, so the rest of this module always runs)."""

    def test_sweep_clock_accumulates_any_program(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @given(st.lists(st.tuples(st.booleans(),
                                  st.integers(min_value=0,
                                              max_value=10_000)),
                        max_size=30))
        @settings(max_examples=50, deadline=None)
        def check(program):
            clock = SweepClock()
            total = 0.0
            for is_chunk, amount in program:
                if is_chunk:
                    clock.on_chunk(amount)
                else:
                    clock.advance(float(amount))
                total += float(amount)
            assert clock() == pytest.approx(total)

        check()

    def test_ridge_features_fixed_width_and_finite(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        kinds = st.recursive(
            st.one_of(st.integers(min_value=-10**6, max_value=10**6),
                      st.text(max_size=3), st.booleans()),
            lambda inner: st.tuples(inner, inner), max_leaves=8)

        @given(kinds, st.floats(min_value=-1e6, max_value=1e6),
               st.lists(st.floats(min_value=-1e3, max_value=1e3),
                        max_size=4))
        @settings(max_examples=100, deadline=None)
        def check(kind, score, extra):
            x = RidgeEffort.features(kind, score, extra)
            assert x.shape == (RidgeEffort.DIM,)
            assert np.isfinite(x).all()
            assert x[0] == 1.0

        check()

    def test_history_roundtrip_predictions_identical(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        obs = st.lists(
            st.tuples(st.sampled_from([(64, 32, 2), (256, 64, 4)]),
                      st.floats(min_value=0.0, max_value=1.0),
                      st.floats(min_value=1.0, max_value=300.0)),
            min_size=0, max_size=12)

        @given(obs, st.floats(min_value=0.0, max_value=1.0))
        @settings(max_examples=50, deadline=None)
        def check(observations, query_score):
            hist = RoundsHistory(capacity=8)
            for kind, score, rounds in observations:
                hist.observe(kind, score, rounds)
            back = RoundsHistory.from_dict(hist.to_dict())
            for kind in [(64, 32, 2), (256, 64, 4), (999, 9, 9)]:
                assert back.expect(kind, query_score, default=-1.0) == \
                    hist.expect(kind, query_score, default=-1.0)

        check()
