"""Roofline tooling: jaxpr flop/byte counter correctness on known
workloads; HLO collective parser on synthetic and real HLO text; the
fused-kernel 3-read/2-write cost-model pin (the autotune loop's contract
-- if the kernel body or the walker drifts from the hand model, the
block-size tuning silently optimizes the wrong target)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (HW, collective_bytes, model_flops)
from repro.roofline.jaxpr_cost import Cost, trace_cost
from repro.roofline.kernel_model import (fused_update_cost, gpu_padded_shape,
                                         predicted_intensity, round_cost)


class TestJaxprCounter:
    def test_plain_matmul(self):
        m, k, n = 64, 128, 256
        a = jax.ShapeDtypeStruct((m, k), jnp.float32)
        b = jax.ShapeDtypeStruct((k, n), jnp.float32)
        c = trace_cost(lambda a, b: a @ b, a, b)
        assert c.flops == 2 * m * k * n
        assert c.bytes == 4 * (m * k + k * n + m * n)

    def test_batched_einsum(self):
        x = jax.ShapeDtypeStruct((8, 16, 32), jnp.float32)
        w = jax.ShapeDtypeStruct((8, 32, 64), jnp.float32)
        c = trace_cost(lambda x, w: jnp.einsum("bik,bkj->bij", x, w), x, w)
        assert c.flops == 2 * 8 * 16 * 32 * 64

    def test_scan_multiplies_by_length(self):
        m, k, L = 64, 128, 7
        def f(ws, x):
            def body(h, w):
                return jnp.tanh(h @ w), None
            return jax.lax.scan(body, x, ws)[0]
        ws = jax.ShapeDtypeStruct((L, k, k), jnp.float32)
        x = jax.ShapeDtypeStruct((m, k), jnp.float32)
        c = trace_cost(f, ws, x)
        dot = 2 * m * k * k
        assert abs(c.flops - L * (dot + m * k)) / (L * dot) < 0.02

    def test_train_step_counts_fwd_bwd_remat(self):
        """fwd + remat-fwd + dW + dh = 4 dots per layer."""
        m, k, L = 64, 128, 4
        def loss(ws, x):
            def body(h, w):
                return jax.checkpoint(lambda h, w: jnp.tanh(h @ w))(h, w), None
            return jnp.sum(jax.lax.scan(body, x, ws)[0] ** 2)
        def step(ws, x):
            _, g = jax.value_and_grad(loss)(ws, x)
            return jax.tree.map(lambda a, b: a - b, ws, g)
        ws = jax.ShapeDtypeStruct((L, k, k), jnp.float32)
        x = jax.ShapeDtypeStruct((m, k), jnp.float32)
        c = trace_cost(step, ws, x)
        expected = L * 4 * 2 * m * k * k
        assert abs(c.flops - expected) / expected < 0.05

    def test_while_trips_hint(self):
        def f(x):
            def cond(c):
                return c[1] < 10
            def body(c):
                x, i = c
                return (jnp.tanh(x @ x), i + 1)
            return jax.lax.while_loop(cond, body, (x, 0))[0]
        x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
        c1 = trace_cost(f, x, while_trips=1.0)
        c10 = trace_cost(f, x, while_trips=10.0)
        assert abs(c10.flops / c1.flops - 10.0) < 0.1


def _kernel_operands(e, s, dtype=jnp.float32):
    return (jax.ShapeDtypeStruct((e, s, s), dtype),
            jax.ShapeDtypeStruct((e, s), dtype),
            jax.ShapeDtypeStruct((e, s), dtype),
            jax.ShapeDtypeStruct((e, s), jnp.bool_))


class TestKernelCostModel:
    """The roofline prediction pin: jaxpr-walk cost of one fused update
    must match the hand-counted 3-read/2-write model. Shapes are chosen
    pre-aligned (power-of-two states, block-multiple edges) so the model
    and the launch agree exactly on bytes; flops get tolerance for the
    O(S) tail, which depends on how jax traces broadcasts."""

    @pytest.mark.parametrize("s,e,dtype", [(2, 1024, jnp.float32),
                                           (4, 1024, jnp.float32),
                                           (8, 512, jnp.float32),
                                           (4, 1024, jnp.bfloat16)])
    @pytest.mark.parametrize("semiring", ["sum", "max"])
    def test_gpu_kernel_matches_model(self, s, e, dtype, semiring):
        from repro.kernels.triton_update import fused_update_e
        db = jnp.dtype(dtype).itemsize
        e_pad, s_pad, _ = gpu_padded_shape(e, s, db)
        assert (e_pad, s_pad) == (e, s)   # pre-aligned by construction
        c = trace_cost(lambda *o: fused_update_e(
            *o, semiring=semiring, interpret=True), *_kernel_operands(e, s, dtype))
        model = fused_update_cost(e, s, dtype_bytes=db, semiring=semiring)
        assert c.bytes == model.bytes     # 3 reads + 2 writes + mask, exact
        assert abs(c.flops - model.flops) / model.flops < 0.25

    def test_tpu_kernel_same_traffic_contract(self):
        """The TPU-layout kernel streams the same operands (transposed), so
        the same byte model holds; flops agree with the sum-semiring fit."""
        from repro.kernels.message_update import fused_update_t
        s, e = 4, 1024
        ops = (jax.ShapeDtypeStruct((s, s, e), jnp.float32),
               jax.ShapeDtypeStruct((s, e), jnp.float32),
               jax.ShapeDtypeStruct((s, e), jnp.float32),
               jax.ShapeDtypeStruct((s, e), jnp.bool_))
        c = trace_cost(lambda *o: fused_update_t(*o, interpret=True), *ops)
        model = fused_update_cost(e, s)
        assert c.bytes == model.bytes
        assert abs(c.flops - model.flops) / model.flops < 0.25

    def test_pallas_flops_scale_with_grid(self):
        """The pallas_call handler multiplies body flops by the grid size:
        doubling the edge count (same block) must double the count."""
        from repro.kernels.triton_update import fused_update_e
        s = 4
        f = lambda *o: fused_update_e(*o, interpret=True, blk_e=256)
        c1 = trace_cost(f, *_kernel_operands(1024, s))
        c2 = trace_cost(f, *_kernel_operands(2048, s))
        assert abs(c2.flops / c1.flops - 2.0) < 1e-6
        assert abs(c2.bytes / c1.bytes - 2.0) < 1e-6

    def test_intensity_memory_bound_and_dtype_scaling(self):
        """BP state counts sit far below the roofline ridge point, and
        halving the operand width must raise intensity (same flops, fewer
        bytes) -- the quantity the BLK_E autotune targets."""
        hw = HW()
        ridge = hw.peak_flops / hw.hbm_bw
        for s in [2, 8, 96]:
            i32 = predicted_intensity(s, dtype_bytes=4)
            i16 = predicted_intensity(s, dtype_bytes=2)
            assert 0.0 < i32 < ridge          # memory-bound everywhere
            assert i16 > i32
        assert predicted_intensity(2, semiring="max") < \
            predicted_intensity(2, semiring="sum")

    def test_round_cost_dominated_by_update(self):
        """Per-scheduler round trace: the fused update is the hot spot, so
        the round's bytes are within a small factor of the kernel's."""
        from repro.core.schedulers import get_scheduler
        from repro.kernels.ops import make_triton_update
        from repro.pgm import ising_grid
        pgm = ising_grid(8, 2.0, seed=0)
        kernel = fused_update_cost(pgm.n_edges, pgm.n_states_max,
                                   padded=True)
        for name in ["lbp", "rbp", "rnbp"]:
            c = round_cost(pgm, get_scheduler(name),
                           make_triton_update(True))
            assert c.flops >= kernel.flops and c.bytes >= kernel.bytes
            assert c.bytes < 6.0 * kernel.bytes


SYNTH_HLO = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  ROOT %r = f32[] add(%a, %b)
}

%wbody (arg: (f32[128,256], s32[])) -> (f32[128,256], s32[]) {
  %ar = f32[128,256]{1,0} all-reduce(%x), to_apply=%add
  %cp = f32[64]{0} collective-permute(%y), source_target_pairs={{0,1}}
  ROOT %t = tuple(%ar, %c)
}

%wcond (arg: (f32[128,256], s32[])) -> pred[] {
  %k = s32[] constant(12)
  ROOT %cmp = pred[] compare(%i, %k), direction=LT
}

ENTRY %main (p: f32[128,256]) -> f32[128,256] {
  %ag = bf16[32,64]{1,0} all-gather(%p2), dimensions={0}
  %w = (f32[128,256], s32[]) while(%init), condition=%wcond, body=%wbody
  ROOT %out = f32[128,256] get-tuple-element(%w), index=0
}
"""


class TestCollectiveParser:
    def test_synthetic_hlo_with_while(self):
        out = collective_bytes(SYNTH_HLO)
        # all-gather once: 32*64*2 bytes
        assert out["all-gather"] == 32 * 64 * 2
        # all-reduce inside 12-trip while, x2 ring factor
        assert out["all-reduce"] == 12 * 128 * 256 * 4 * 2
        assert out["collective-permute"] == 12 * 64 * 4
        assert out["total"] == (out["all-gather"] + out["all-reduce"]
                                + out["collective-permute"])

    def test_lhs_name_not_confused_with_op(self):
        txt = """
ENTRY %main (p: f32[4]) -> f32[4] {
  %all-reduce.5 = f32[4]{0} add(%p, %p)
  ROOT %r = f32[4] copy(%all-reduce.5)
}
"""
        out = collective_bytes(txt)
        assert out["total"] == 0.0

    def test_async_start_done_counted_once(self):
        txt = """
ENTRY %main (p: f32[8,8]) -> f32[8,8] {
  %ars = (f32[8,8], f32[8,8]) all-reduce-start(%p), to_apply=%add
  ROOT %ard = f32[8,8] all-reduce-done(%ars)
}
"""
        out = collective_bytes(txt)
        assert out["all-reduce"] == 8 * 8 * 4 * 2   # once, with ring factor


class TestModelFlops:
    def test_moe_active_fraction(self):
        from repro.configs import get
        from repro.models import build_model
        cfg = get("granite_moe_3b_a800m")
        specs = build_model(cfg).param_specs()
        mf_all = model_flops(specs, 1000, cfg=None, kind="train")
        mf_active = model_flops(specs, 1000, cfg=cfg, kind="train")
        assert mf_active < mf_all          # expert scaling applied
        # experts are 40, top-8 -> expert flops scaled by 0.2
        assert mf_active > 0.1 * mf_all

    def test_serve_multiplier(self):
        from repro.configs import get
        from repro.models import build_model
        cfg = get("qwen3_4b")
        specs = build_model(cfg).param_specs()
        assert model_flops(specs, 100, cfg=cfg, kind="train") == \
            3 * model_flops(specs, 100, cfg=cfg, kind="decode")
