"""Optimized-path equivalence: the SSPerf variants must compute the same
math as the baselines they replace."""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.models import build_model
from repro.models.layers.moe import init_moe, moe


class TestMoEDispatchParity:
    def _setup(self, e=8, d=32, ff=16, t=64, k=2, seed=0):
        key = jax.random.key(seed)
        p = init_moe(key, d, e, ff)
        x = jax.random.normal(jax.random.fold_in(key, 1), (2, t // 2, d),
                              jnp.float32)
        return p, x, e, k

    def test_dense_equals_ragged(self):
        p, x, e, k = self._setup()
        out_r, aux_r = moe(p, x, n_experts=e, top_k=k, dispatch="ragged")
        out_d, aux_d = moe(p, x, n_experts=e, top_k=k, dispatch="dense")
        np.testing.assert_allclose(np.asarray(out_r), np.asarray(out_d),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(float(aux_r[0]), float(aux_d[0]),
                                   rtol=1e-5)

    def test_sharded_equals_ragged_subprocess(self):
        """sharded dispatch on 4 fake devices == ragged on one."""
        if not hasattr(jax, "shard_map"):
            pytest.skip("jax.shard_map requires a newer jax")
        code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.models.layers.moe import init_moe, moe, set_shard_mesh

mesh = jax.make_mesh((2, 2), ("data", "model"))
set_shard_mesh(mesh)
key = jax.random.key(0)
e, d, ff, t, k = 8, 32, 16, 64, 2
p = init_moe(key, d, e, ff)
x = jax.random.normal(jax.random.fold_in(key, 1), (2, t // 2, d),
                      jnp.float32)
out_r, _ = moe(p, x, n_experts=e, top_k=k, dispatch="ragged")
with mesh:
    out_s, _ = jax.jit(lambda p, x: moe(p, x, n_experts=e, top_k=k,
                                        dispatch="sharded"))(p, x)
np.testing.assert_allclose(np.asarray(out_r), np.asarray(out_s),
                           atol=1e-4, rtol=1e-4)
print("OK")
"""
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout


class TestBandedBP:
    def test_banded_matches_reference_subprocess(self):
        code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.core import LBP, run_bp
from repro.pgm import ising_grid_fast, chain_graph
from repro.dist.bp_banded import partition_banded, run_bp_banded

mesh = jax.make_mesh((8,), ("bp",))
for pgm in [ising_grid_fast(24, 2.5, seed=0), chain_graph(2000, seed=0)]:
    ref = run_bp(pgm, LBP(), jax.random.key(0), eps=1e-5, max_rounds=6000)
    part = partition_banded(pgm, 8)
    logm, rounds, done = run_bp_banded(part, LBP(), mesh,
                                       jax.random.key(0), eps=1e-5,
                                       max_rounds=6000)
    assert bool(done), "banded LBP did not converge"
    # LBP is deterministic: identical round count == identical trajectory
    assert int(rounds) == int(ref.rounds), (int(rounds), int(ref.rounds))
print("OK")
"""
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout

    def test_partition_rejects_unbanded(self):
        from repro.dist.bp_banded import partition_banded
        from repro.pgm import protein_like_graph
        pgm = protein_like_graph(60, seed=0)  # irregular spatial graph
        with pytest.raises(AssertionError):
            partition_banded(pgm, 32)

    def test_banded_relaxed_converges_subprocess(self):
        # rlx/rlxtree are first-class on the banded path: shard-local
        # per-queue top-k, no global sort. Same 8-fake-device subprocess
        # pattern as the LBP parity test above.
        code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.core import RLX, RLXTree
from repro.pgm import ising_grid_fast
from repro.dist.bp_banded import partition_banded, run_bp_banded

mesh = jax.make_mesh((8,), ("bp",))
pgm = ising_grid_fast(24, 2.5, seed=0)
part = partition_banded(pgm, 8)
for sched in [RLX(), RLXTree()]:
    logm, rounds, done = run_bp_banded(part, sched, mesh, jax.random.key(0),
                                       eps=1e-4, max_rounds=10000)
    assert bool(done), f"banded {type(sched).__name__} did not converge"
print("OK")
"""
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout

    def test_banded_unsupported_scheduler_error_lists_rlx(self):
        # exact sort-based schedulers are rejected with the uniform
        # registry-style message that names the supported subset
        from repro.core import RBP, RS
        from repro.dist.bp_banded import partition_banded, run_bp_banded
        from repro.pgm import ising_grid_fast
        mesh = jax.make_mesh((1,), ("bp",))
        part = partition_banded(ising_grid_fast(6, 1.0, seed=0), 1)
        for sched in (RBP(), RS()):
            with pytest.raises(NotImplementedError) as ei:
                run_bp_banded(part, sched, mesh, jax.random.key(0))
            msg = str(ei.value)
            assert "unknown banded scheduler" in msg
            assert "'rlx'" in msg and "'rlxtree'" in msg
            assert "'lbp'" in msg and "'rnbp'" in msg


class TestFSDPShardings:
    def test_fsdp_param_rules(self):
        from jax.sharding import PartitionSpec as P
        from repro.launch.sharding import _fsdp_pspec

        class E:
            def __init__(self, k):
                self.key = k
        leaf = jax.ShapeDtypeStruct((12288, 28672), jnp.float32)
        spec = _fsdp_pspec((E("w_in"),), leaf, ("data", "model"), 256, False)
        assert spec == P(None, ("data", "model"))   # output dim sharded
        small = jax.ShapeDtypeStruct((12288,), jnp.float32)
        assert _fsdp_pspec((E("ln1"),), small, ("data", "model"), 256,
                           False) == P(None)        # small leaf replicated
