"""Batched multi-graph engine (bucketed BPEngine) vs per-graph runs.

The contract under test: a graph inside a padded bucket reproduces its solo
trajectory -- same rounds, same committed messages, beliefs equal to float
tolerance -- for every scheduler, and the disjoint-union fold / Pallas batch
path match the reference update.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BPConfig, BPEngine, LBP, RBP, RS, RnBP, BatchedPGM,
                        batch_keys, bucket_pgms, messages as M, pad_pgm)
from repro.kernels.ops import make_pallas_update_batch, pallas_update_batch
from repro.pgm import chain_graph, ising_grid, loop_graph, protein_like_graph

SCHEDULERS = [LBP(), RBP(p=1.0 / 16), RS(p=0.05), RnBP(low_p=0.4, high_p=0.9)]


def engine(sched, **cfg) -> BPEngine:
    return BPEngine(BPConfig(scheduler=sched, **cfg))


def mixed_pgms():
    """16-graph mixed-size grid/chain/loop set (one padded bucket)."""
    return ([ising_grid(n, 2.0, seed=n) for n in (5, 6, 7, 8, 9)]
            + [chain_graph(n, seed=n) for n in (30, 50, 80, 120, 160)]
            + [loop_graph(n, seed=n) for n in (16, 24, 40, 64, 96, 128)])


def _belief_diff(a, b):
    return float(jnp.max(jnp.abs(jnp.where(jnp.isfinite(b), a - b, 0.0))))


class TestBatchParity:
    @pytest.mark.parametrize("sched", SCHEDULERS,
                             ids=lambda s: type(s).__name__)
    def test_batch_matches_per_graph(self, sched):
        pgms = mixed_pgms()
        batch = BatchedPGM.from_pgms(pgms)
        assert batch.size == 16
        keys = batch_keys(jax.random.key(0), batch)
        eng = engine(sched, eps=1e-4, max_rounds=600, history=False)
        res = eng.run(batch, keys)
        for i in range(batch.size):
            solo = eng.run(batch.graph(i), keys[i])
            assert int(res.rounds[i]) == int(solo.rounds), f"graph {i}"
            assert bool(res.converged[i]) == bool(solo.converged)
            assert _belief_diff(res.beliefs[i], solo.beliefs) < 1e-5, \
                f"graph {i}"

    def test_padding_is_inert(self):
        """BP on a bucket-padded graph == BP on the original
        (LBP: deterministic, shape-independent selection)."""
        pgm = ising_grid(7, 2.0, seed=3)
        padded = pad_pgm(pgm, n_edges=pgm.n_edges + 256,
                         n_vertices=pgm.n_vertices + 16,
                         n_states=pgm.n_states_max + 3)
        eng = engine(LBP(), eps=1e-4)
        a = eng.run(pgm, jax.random.key(0))
        b = eng.run(padded, jax.random.key(0))
        assert int(a.rounds) == int(b.rounds)
        v, s = pgm.n_real_vertices, pgm.n_states_max
        np.testing.assert_allclose(np.asarray(a.beliefs[:v]),
                                   np.asarray(b.beliefs[:v, :s]), atol=1e-5)

    def test_per_graph_convergence_and_rounds(self):
        """Fast graphs freeze (rounds, updates) while stragglers finish."""
        pgms = [chain_graph(20, seed=1), ising_grid(9, 2.5, seed=11)]
        batch = BatchedPGM.from_pgms(pgms)
        keys = batch_keys(jax.random.key(2), batch)
        res = engine(RnBP(low_p=0.4, high_p=0.9), eps=1e-4,
                     max_rounds=800, history=False).run(batch, keys)
        r = np.asarray(res.rounds)
        assert bool(res.converged[0]) and bool(res.converged[1])
        assert r[0] < r[1]  # the chain converged first and froze


class TestBucketing:
    def test_buckets_cover_and_bound_padding(self):
        pgms = mixed_pgms() + [protein_like_graph(40, seed=5)]
        buckets = bucket_pgms(pgms)
        seen = sorted(i for b in buckets for i in b.indices)
        assert seen == list(range(len(pgms)))
        for b in buckets:
            for i in b.indices:
                # pow2 bucketing: <= 2x padding on the edge axis
                assert b.batch.n_edges <= 2 * max(pgms[i].n_edges, 128)
        # the 81-state protein graph must not share a bucket with S=2 graphs
        for b in buckets:
            smax = {pgms[i].n_states_max for i in b.indices}
            assert len({1 << (s - 1).bit_length() for s in smax}) == 1

    def test_growth_inf_single_bucket(self):
        pgms = mixed_pgms()
        buckets = bucket_pgms(pgms, growth=math.inf)
        assert len(buckets) == 1 and len(buckets[0].indices) == len(pgms)

    def test_max_batch_splits(self):
        pgms = [chain_graph(30, seed=s) for s in range(7)]
        buckets = bucket_pgms(pgms, max_batch=3)
        assert [len(b.indices) for b in buckets] == [3, 3, 1]

    def test_run_many_order_and_bucket_invariance(self):
        pgms = mixed_pgms()
        eng = engine(LBP(), eps=1e-4, max_rounds=600, history=False)
        res_fine = eng.run_many(pgms, jax.random.key(0))
        res_one = eng.run_many(pgms, jax.random.key(0), growth=math.inf)
        assert len(res_fine) == len(pgms)
        for i, pgm in enumerate(pgms):
            assert bool(res_fine[i].converged)
            v, s = pgm.n_real_vertices, pgm.n_states_max
            np.testing.assert_allclose(
                np.asarray(res_fine[i].beliefs[:v, :s]),
                np.asarray(res_one[i].beliefs[:v, :s]), atol=1e-5)


class TestFoldedUpdates:
    def test_union_fold_matches_vmapped_ref(self):
        batch = BatchedPGM.from_pgms(
            [ising_grid(6, 2.0, seed=s) for s in range(3)]
            + [chain_graph(40, seed=7)])
        union = batch.folded()
        b, e, s = batch.size, batch.n_edges, batch.n_states_max
        logm = jax.vmap(M.init_messages)(batch.pgm)
        c_v, r_v = jax.vmap(M.ref_update)(batch.pgm, logm)
        c_u, r_u = M.ref_update(union, logm.reshape(b * e, s))
        np.testing.assert_array_equal(np.asarray(c_v.reshape(b * e, s)),
                                      np.asarray(c_u))
        np.testing.assert_array_equal(np.asarray(r_v.reshape(-1)),
                                      np.asarray(r_u))

    def test_pallas_batch_fold_matches_ref(self):
        batch = BatchedPGM.from_pgms(
            [ising_grid(6, 2.0, seed=s) for s in range(3)]
            + [chain_graph(40, seed=7)])
        logm = jax.vmap(M.init_messages)(batch.pgm)
        c_ref, r_ref = jax.vmap(M.ref_update)(batch.pgm, logm)
        c_k, r_k = pallas_update_batch(batch.pgm, logm, interpret=True)
        mask = np.asarray(
            jax.vmap(lambda p: p.state_mask[p.edge_dst])(batch.pgm))
        np.testing.assert_allclose(
            np.where(mask, np.asarray(c_k), 0.0),
            np.where(mask, np.asarray(c_ref), 0.0), atol=1e-5)
        np.testing.assert_allclose(np.asarray(r_k), np.asarray(r_ref),
                                   atol=1e-5)

    def test_e2e_batch_with_pallas_update(self):
        """Whole-bucket BP through the folded Pallas kernel converges to the
        reference fixed point (trajectories may differ within eps)."""
        batch = BatchedPGM.from_pgms([ising_grid(6, 2.0, seed=s)
                                      for s in range(3)])
        keys = batch_keys(jax.random.key(1), batch)
        ref = engine(RnBP(), eps=1e-4, max_rounds=400,
                     history=False).run(batch, keys)
        ker = engine(RnBP(), eps=1e-4, max_rounds=400, history=False,
                     batch_backend=make_pallas_update_batch(True)
                     ).run(batch, keys)
        assert bool(jnp.all(ker.converged))
        assert _belief_diff(ker.beliefs, ref.beliefs) < 1e-3
