"""Core BP behaviour: exactness on trees, fixed-point agreement across
schedulers, convergence semantics, serial-parallel parity (paper Fig 5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LBP, RBP, RS, RnBP, brute_force_marginals,
                        kl_divergence, run_bp, run_srbp, ve_marginals)
from repro.core import messages as M
from repro.core.graph import build_pgm
from repro.pgm import (chain_graph, ising_grid, ising_grid_fast,
                       protein_like_graph, small_ising)

SCHEDULERS = [LBP(), RBP(p=0.1), RS(p=0.1, h=2), RnBP(low_p=0.7)]


def _marginals(res, nv, ns=2):
    return np.exp(np.asarray(res.beliefs, dtype=np.float64))[:nv, :ns]


class TestTreeExactness:
    """BP is exact on trees -- every scheduler must match brute force."""

    @pytest.mark.parametrize("sched", SCHEDULERS,
                             ids=lambda s: type(s).__name__)
    def test_chain_exact(self, sched):
        pgm = chain_graph(12, C=3.0, seed=3)
        edges = np.stack([np.arange(11), np.arange(1, 12)], 1)
        # rebuild potentials for the oracle
        rng = np.random.default_rng(3)
        unary = [rng.uniform(1e-3, 1.0, size=2) for _ in range(12)]
        lam = rng.uniform(-0.5, 0.5, size=11)
        pair = [np.array([[np.exp(l * 3.0), np.exp(-l * 3.0)],
                          [np.exp(-l * 3.0), np.exp(l * 3.0)]]) for l in lam]
        exact = brute_force_marginals(12, edges, unary, pair)
        # eps floor: messages are f32, residuals plateau ~2e-7
        res = run_bp(pgm, sched, jax.random.key(0), eps=1e-6,
                     max_rounds=3000)
        assert bool(res.converged)
        got = _marginals(res, 12)
        np.testing.assert_allclose(got, np.stack(exact), atol=2e-4)


class TestFixedPointAgreement:
    """All schedulers converge to the same BP fixed point on loopy graphs."""

    def test_ising_schedulers_agree(self):
        pgm = ising_grid(8, 2.0, seed=1)
        results = []
        for sched in SCHEDULERS:
            res = run_bp(pgm, sched, jax.random.key(1), eps=1e-6,
                         max_rounds=5000)
            assert bool(res.converged), type(sched).__name__
            results.append(_marginals(res, 64))
        for r in results[1:]:
            np.testing.assert_allclose(r, results[0], atol=1e-4)

    def test_serial_parity_fig5(self):
        """Paper Fig 5: RnBP marginal quality == SRBP vs exact (VE)."""
        pgm, nv, edges, unary, pairwise = small_ising(6, 2.0, seed=2)
        exact = ve_marginals(nv, edges, unary, pairwise)
        res = run_bp(pgm, RnBP(low_p=0.7), jax.random.key(0), eps=1e-6,
                     max_rounds=4000)
        sr = run_srbp(pgm, eps=1e-6)
        assert bool(res.converged) and sr.converged
        kl_r = [kl_divergence(exact[v], _marginals(res, nv)[v])
                for v in range(nv)]
        kl_s = [kl_divergence(exact[v], np.exp(sr.beliefs[v, :2]))
                for v in range(nv)]
        # same quality within 10% relative or 1e-4 absolute
        assert abs(np.mean(kl_r) - np.mean(kl_s)) < max(
            1e-4, 0.1 * np.mean(kl_s))


class TestConvergenceSemantics:
    def test_unconverged_reported(self):
        # C=3 hard grid, tiny round budget -> must NOT claim convergence
        pgm = ising_grid(20, 3.0, seed=0)
        res = run_bp(pgm, LBP(), jax.random.key(0), eps=1e-5, max_rounds=3)
        assert not bool(res.converged)
        assert int(res.rounds) == 3

    def test_history_monotone_rounds(self):
        pgm = ising_grid(10, 2.0, seed=0)
        res = run_bp(pgm, LBP(), jax.random.key(0), eps=1e-4,
                     max_rounds=500)
        hist = np.asarray(res.unconverged_history)
        used = hist[hist >= 0]
        # final round records unconverged==0 without incrementing rounds
        assert int(res.rounds) <= len(used) <= int(res.rounds) + 1
        assert used[-1] == 0 or bool(res.converged)

    def test_messages_normalized(self):
        pgm = protein_like_graph(40, seed=5)
        res = run_bp(pgm, RnBP(low_p=0.4), jax.random.key(0), eps=1e-4,
                     max_rounds=2000)
        logm = np.asarray(res.logm, dtype=np.float64)
        mask = np.asarray(pgm.state_mask[pgm.edge_dst])
        emask = np.asarray(pgm.edge_mask)
        z = np.log(np.sum(np.where(mask, np.exp(logm), 0.0), axis=1))
        np.testing.assert_allclose(z[emask], 0.0, atol=1e-3)

    def test_beliefs_normalized(self):
        pgm = ising_grid(6, 2.5, seed=4)
        res = run_bp(pgm, LBP(), jax.random.key(0), max_rounds=500)
        b = np.exp(np.asarray(res.beliefs, np.float64))[:36]
        np.testing.assert_allclose(b.sum(1), 1.0, atol=1e-4)


class TestFastBuilder:
    def test_fast_matches_loop_builder(self):
        a = ising_grid(7, 2.5, seed=9)
        b = ising_grid_fast(7, 2.5, seed=9)
        # same distribution family & shapes; same seed gives same unary sums
        assert a.n_edges == b.n_edges
        assert a.n_real_vertices == b.n_real_vertices
        res_a = run_bp(a, LBP(), jax.random.key(0), max_rounds=500)
        res_b = run_bp(b, LBP(), jax.random.key(0), max_rounds=500)
        assert bool(res_a.converged) and bool(res_b.converged)
