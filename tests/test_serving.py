"""Async serving pipeline (repro.core.serving): bitwise parity with the
legacy driver, bucket compaction's wasted-sweep reduction, and the online
request-iterator path.

The load-bearing invariant under test: a graph's trajectory depends only on
its own padded shape and RNG key, so slot count, prefetch, backfill order,
and compaction may change *scheduling* (sweep accounting, completion order)
but never a result bit.
"""

import time

import jax
import numpy as np
import pytest

from repro.core import (ADMISSION_POLICIES, AdmissionPolicy, BPConfig,
                        BPEngine, BatchedPGM, FIFOAdmission, RoundsHistory,
                        ServingPipeline, get_admission_policy,
                        register_admission_policy, serve_async)
from repro.core.batch import bucket_shape
from repro.pgm import chain_graph, ising_grid


def _straggler_stream():
    # LBP deterministic: C=1.5 converges in tens of rounds while
    # ising(8, 3.5, seed=0) stalls to max_rounds. Same shape -> one group.
    fast = [ising_grid(8, 1.5, seed=s) for s in range(8)]
    return fast[:4] + [ising_grid(8, 3.5, seed=0)] + fast[4:], 4


def _lbp_engine(max_rounds=320):
    return BPEngine(BPConfig(scheduler="lbp", eps=1e-5,
                             max_rounds=max_rounds, history=False))


def _assert_bitwise(got, want):
    assert int(got.rounds) == int(want.rounds)
    assert int(got.updates) == int(want.updates)
    np.testing.assert_array_equal(np.asarray(got.logm), np.asarray(want.logm))


class TestServeAsyncParity:
    """Acceptance: serve_async on a materialized stream is bitwise-identical
    to legacy serve (and to run_many where padded shapes coincide)."""

    def test_bitwise_matches_serve_mixed_shapes_rnbp(self):
        stream = [ising_grid(6, 2.0, seed=1), chain_graph(40, seed=2),
                  ising_grid(7, 2.0, seed=3), chain_graph(50, seed=4),
                  chain_graph(45, seed=5), ising_grid(6, 2.2, seed=6),
                  chain_graph(60, seed=7)]
        engine = BPEngine(BPConfig(scheduler="rnbp",
                                   scheduler_kwargs={"low_p": 0.4},
                                   eps=1e-4, max_rounds=400, history=False))
        kw = dict(max_batch=2, chunk_rounds=32)
        legacy = engine.serve(stream, jax.random.key(0), **kw)
        rep = serve_async(engine, stream, jax.random.key(0),
                          compact=True, slots=2, **kw)
        assert len(rep.results) == len(stream)
        for got, want in zip(rep.results, legacy.results):
            _assert_bitwise(got, want)
        # scheduling may differ; the work accounted as useful may not
        assert rep.stats.useful_sweeps == legacy.stats.useful_sweeps

    def test_bitwise_matches_run_many_same_shape(self):
        stream, _ = _straggler_stream()
        engine = BPEngine(BPConfig(scheduler="rnbp",
                                   scheduler_kwargs={"low_p": 0.4},
                                   eps=1e-4, max_rounds=320, history=False))
        rep = serve_async(engine, stream, jax.random.key(3), max_batch=3,
                          chunk_rounds=48, compact=True, slots=2)
        ref = engine.run_many(stream, jax.random.key(3), max_batch=3)
        for got, want in zip(rep.results, ref):
            _assert_bitwise(got, want)

    def test_serial_scheduler_rejected(self):
        engine = BPEngine(BPConfig(scheduler="srbp"))
        with pytest.raises(NotImplementedError):
            ServingPipeline(engine, jax.random.key(0))


class TestCompaction:
    """Satellite: once the pending queue drains, survivors re-bucket into a
    narrower batch, so dead slots stop costing sweeps -- the term evacuation
    alone cannot remove."""

    def test_post_drain_rebucket_reduces_wasted_sweeps(self):
        stream, slow_i = _straggler_stream()
        engine = _lbp_engine()
        kw = dict(max_batch=3, chunk_rounds=64, slots=1)
        evac = serve_async(engine, stream, jax.random.key(0),
                           compact=False, **kw)
        comp = serve_async(engine, stream, jax.random.key(0),
                           compact=True, **kw)
        # same graphs do the same useful work; compaction only sheds waste
        assert comp.stats.useful_sweeps == evac.stats.useful_sweeps
        assert comp.stats.compactions >= 1
        assert comp.stats.wasted_sweeps < evac.stats.wasted_sweeps
        assert comp.stats.device_sweeps < evac.stats.device_sweeps
        # the straggler survives compaction with its trajectory intact
        for got, want in zip(comp.results, evac.results):
            _assert_bitwise(got, want)
        assert not bool(comp.results[slow_i].converged)
        # widths in the log shrink monotonically and stay pow2
        for _, before, after in comp.stats.compaction_log:
            assert after < before
            assert after & (after - 1) == 0

    def test_batched_pgm_take_preserves_graphs(self):
        pgms = [ising_grid(6, 2.0, seed=s) for s in range(4)]
        batch = BatchedPGM.from_pgms(pgms)
        sub = batch.take([0, 2])
        assert sub.size == 2
        for want, j in [(0, 0), (2, 1)]:
            got, ref = sub.graph(j), batch.graph(want)
            for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestOnlineStream:
    """The pipeline accepts a lazy iterator: requests are staged as pulled,
    padded to per-request ``bucket_shape`` ceilings, and each reproduces its
    solo trajectory (LBP is padding-invariant on real edges)."""

    def test_online_iterator_matches_solo_runs(self):
        stream, _ = _straggler_stream()
        engine = _lbp_engine()
        rep = serve_async(engine, iter(stream), jax.random.key(0),
                          max_batch=3, chunk_rounds=64, prefetch=4, slots=2)
        assert len(rep.records) == len(stream)
        assert sorted(r.rid for r in rep.records) == list(range(len(stream)))
        for rec in rep.records:
            want = engine.run(stream[rec.rid],
                              jax.random.fold_in(jax.random.key(0), rec.rid))
            got = rec.result
            assert int(got.rounds) == int(want.rounds)
            rv = stream[rec.rid].n_real_vertices
            s0 = want.beliefs.shape[1]
            np.testing.assert_allclose(
                np.asarray(got.beliefs)[:rv, :s0],
                np.asarray(want.beliefs)[:rv], atol=1e-6)

    def test_latency_timeline_and_percentiles(self):
        stream, _ = _straggler_stream()
        engine = _lbp_engine(max_rounds=128)
        rep = serve_async(engine, iter(stream), jax.random.key(0),
                          max_batch=4, chunk_rounds=32)
        for rec in rep.records:
            assert rec.t_done >= rec.t_admit >= rec.t_enqueue
            assert rec.latency_s == pytest.approx(
                rec.queue_s + rec.service_s)
        pct = rep.latency_percentiles((50, 99))
        assert pct["p50"] <= pct["p99"]
        assert rep.stats.staged == len(stream)

    def test_lazy_pull_bounded_by_prefetch(self):
        """With prefetch=k the host never pulls the whole stream up front:
        the pull position stays within k of staged-but-unserved work."""
        stream, _ = _straggler_stream()
        pulled = []

        def gen():
            for i, p in enumerate(stream):
                pulled.append(i)
                yield p

        engine = _lbp_engine(max_rounds=128)
        pipe = ServingPipeline(engine, jax.random.key(0), max_batch=2,
                               chunk_rounds=32, prefetch=2)
        seen = 0
        for _ in pipe.serve(gen()):
            seen += 1
            # at most (resident slots * width) + prefetch ahead of releases
            assert len(pulled) <= seen + 2 * 2 + 2
        assert seen == len(stream)

    def test_dead_slots_revived_by_later_arrivals(self):
        """A slot that empties while its group queue is momentarily dry
        must be backfilled once same-shape requests arrive -- and staged
        work from *other* groups must not block pulling them (hunger-aware
        prefetch). Without both, the late ising graphs would wait out the
        straggler's entire run on a dead slot."""
        straggler = ising_grid(8, 3.5, seed=0)
        fast = [ising_grid(8, 1.5, seed=s) for s in range(4)]
        chains = [chain_graph(40, seed=1), chain_graph(40, seed=2)]
        stream = [straggler, fast[0]] + chains + fast[1:]
        engine = _lbp_engine(max_rounds=384)
        rep = serve_async(engine, iter(stream), jax.random.key(0),
                          max_batch=2, chunk_rounds=48, slots=1, prefetch=2)
        assert len(rep.records) == len(stream)
        assert rep.stats.backfilled > 0
        # the late fast graphs ride the straggler's bucket, so they finish
        # before the straggler exhausts max_rounds
        order = [r.rid for r in rep.records]
        assert order.index(0) > max(order.index(i) for i in (4, 5))

    def test_explicit_sparse_rids_and_empty_stream(self):
        """(rid, PGM) streams may use sparse rids (results leaves None
        gaps), duplicate rids are rejected (the rid is the RNG fold_in
        index), and an empty stream serves cleanly."""
        engine = _lbp_engine(max_rounds=128)
        rep = serve_async(engine, iter([(5, ising_grid(6, 1.5, seed=0))]),
                          jax.random.key(0))
        assert len(rep.results) == 6
        assert rep.results[5] is not None
        assert all(r is None for r in rep.results[:5])
        with pytest.raises(ValueError, match="duplicate"):
            serve_async(engine, iter([(3, ising_grid(6, 1.5, seed=0)),
                                      (3, ising_grid(6, 1.5, seed=1))]),
                        jax.random.key(0))
        empty = serve_async(engine, iter([]), jax.random.key(0))
        assert empty.records == [] and empty.results == []
        assert np.isnan(empty.latency_percentiles()["p50"])

    def test_bucket_shape_is_deterministic_and_padable(self):
        for p in [ising_grid(6, 2.0, seed=0), chain_graph(33, seed=1)]:
            e, v, s, re_, rv = bucket_shape(p)
            assert e >= p.n_edges and v >= p.n_vertices
            assert s >= p.n_states_max
            assert re_ >= p.n_real_edges and rv >= p.n_real_vertices
            assert bucket_shape(p) == (e, v, s, re_, rv)
        with pytest.raises(ValueError):
            bucket_shape(ising_grid(4, 2.0, seed=0), growth=float("inf"))


def _effort_mix_stream():
    # 16 fast + 4 slow (every 5th), one shape family: the residual policy
    # must separate them into effort-homogeneous buckets.
    fast = [ising_grid(10, 1.5, seed=s) for s in range(16)]
    slow = [ising_grid(10, 3.5, seed=s) for s in range(4)]
    stream, fi, si = [], 0, 0
    for i in range(20):
        if i % 5 == 3:
            stream.append(slow[si]); si += 1
        else:
            stream.append(fast[fi]); fi += 1
    return stream


class TestAdmissionPolicies:
    """Tentpole: pluggable admission. policy="fifo" is bitwise the PR-4
    pipeline (results AND sweep accounting); "residual" co-batches by
    expected effort without touching any result bit; "windowed" trades an
    admission delay for fuller buckets; the registry accepts custom
    policies."""

    def test_fifo_explicit_matches_default_bitwise_and_stats(self):
        stream = [ising_grid(6, 2.0, seed=1), chain_graph(40, seed=2),
                  ising_grid(7, 2.0, seed=3), chain_graph(50, seed=4),
                  chain_graph(45, seed=5), ising_grid(6, 2.2, seed=6)]
        engine = BPEngine(BPConfig(scheduler="rnbp",
                                   scheduler_kwargs={"low_p": 0.4},
                                   eps=1e-4, max_rounds=400, history=False))
        kw = dict(max_batch=2, chunk_rounds=32, slots=2)
        default = serve_async(engine, stream, jax.random.key(0), **kw)
        explicit = serve_async(engine, stream, jax.random.key(0),
                               admission="fifo", **kw)
        assert explicit.stats.policy == "fifo"
        for got, want in zip(explicit.results, default.results):
            _assert_bitwise(got, want)
        for f in ("chunks", "device_sweeps", "useful_sweeps", "evacuated",
                  "backfilled", "buckets_opened", "admission_widths"):
            assert getattr(explicit.stats, f) == getattr(default.stats, f)

    @pytest.mark.parametrize("admission,kwargs", [
        ("residual", {}),
        ("windowed", {"window_s": 0.0}),
    ])
    def test_policies_never_change_results(self, admission, kwargs):
        # Trajectory invariance: same padded shapes + fold_in(rng, rid)
        # keys make admission order bitwise-invisible, even for the
        # stochastic scheduler.
        stream = [ising_grid(6, 2.0, seed=1), chain_graph(40, seed=2),
                  ising_grid(7, 2.0, seed=3), chain_graph(50, seed=4)]
        engine = BPEngine(BPConfig(scheduler="rnbp",
                                   scheduler_kwargs={"low_p": 0.4},
                                   eps=1e-4, max_rounds=400, history=False))
        kw = dict(max_batch=2, chunk_rounds=32, slots=1, prefetch=None)
        fifo = serve_async(engine, stream, jax.random.key(0),
                           admission="fifo", **kw)
        other = serve_async(engine, stream, jax.random.key(0),
                            admission=admission, admission_kwargs=kwargs,
                            **kw)
        for got, want in zip(other.results, fifo.results):
            _assert_bitwise(got, want)

    def test_policies_invariant_for_relaxed_scheduler(self):
        # Same invariance with the relaxed priority family: rlx's queue
        # sampling draws from the per-request fold_in stream, so admission
        # order must stay bitwise-invisible for it too.
        stream = [ising_grid(6, 2.0, seed=1), chain_graph(40, seed=2),
                  ising_grid(7, 2.0, seed=3)]
        engine = BPEngine(BPConfig(scheduler="rlx",
                                   scheduler_kwargs={"p": 1 / 32},
                                   eps=1e-4, max_rounds=600, history=False))
        kw = dict(max_batch=2, chunk_rounds=32, slots=1, prefetch=None)
        fifo = serve_async(engine, stream, jax.random.key(0),
                           admission="fifo", **kw)
        resid = serve_async(engine, stream, jax.random.key(0),
                            admission="residual", **kw)
        for got, want in zip(resid.results, fifo.results):
            _assert_bitwise(got, want)

    def test_residual_cobatching_cuts_wasted_sweeps(self):
        """Acceptance: residual admission <= FIFO wasted sweeps at equal
        slots on the straggler mix, with identical useful work."""
        stream = _effort_mix_stream()
        engine = BPEngine(BPConfig(scheduler="lbp", eps=1e-5,
                                   max_rounds=384, history=False))
        kw = dict(max_batch=4, chunk_rounds=48, slots=1, compact=False,
                  prefetch=None)
        fifo = serve_async(engine, stream, jax.random.key(0),
                           admission="fifo", **kw)
        resid = serve_async(engine, stream, jax.random.key(0),
                            admission="residual", **kw)
        assert resid.stats.useful_sweeps == fifo.stats.useful_sweeps
        assert resid.stats.wasted_sweeps <= fifo.stats.wasted_sweeps
        assert resid.stats.device_sweeps < fifo.stats.device_sweeps
        for got, want in zip(resid.results, fifo.results):
            _assert_bitwise(got, want)

    def test_residual_no_starvation_aging(self):
        """A straggler the similarity rule keeps skipping is force-admitted
        after `aging` takes once it reaches the queue head -- it must not
        wait out the whole fast stream."""
        stream = ([ising_grid(8, 1.5, seed=0), ising_grid(8, 1.5, seed=1),
                   ising_grid(8, 3.5, seed=0)]
                  + [ising_grid(8, 1.5, seed=s) for s in range(2, 26)])
        slow_rid = 2
        engine = _lbp_engine(max_rounds=384)
        rep = serve_async(engine, stream, jax.random.key(0), max_batch=2,
                          chunk_rounds=32, slots=1, compact=False,
                          prefetch=None, admission="residual",
                          admission_kwargs={"aging": 4})
        assert sorted(r.rid for r in rep.records) == list(range(len(stream)))
        by_rid = {r.rid: r for r in rep.records}
        admitted_after_slow = sum(
            1 for r in rep.records if r.t_admit > by_rid[slow_rid].t_admit)
        # forced admission happened well before the fast queue drained
        assert admitted_after_slow >= 10

    def test_windowed_gathers_fuller_buckets(self):
        """With a huge window the first bucket fills to max_batch before
        opening (FIFO opens at the prefetch watermark); exhaustion makes
        the tail admissible so nothing waits out the window."""
        def online():
            for s in range(6):
                yield ising_grid(6, 1.5, seed=s)

        engine = _lbp_engine(max_rounds=160)
        kw = dict(max_batch=4, chunk_rounds=64, slots=1, prefetch=2)
        fifo = serve_async(engine, online(), jax.random.key(0), **kw)
        wind = serve_async(engine, online(), jax.random.key(0),
                           admission="windowed",
                           admission_kwargs={"window_s": 30.0}, **kw)
        assert fifo.stats.admission_widths[0] == 2
        assert wind.stats.admission_widths[0] == 4
        assert wind.stats.admission_holds >= 1
        assert sorted(r.rid for r in wind.records) == list(range(6))
        for got, want in zip(wind.results, fifo.results):
            _assert_bitwise(got, want)

    def test_registry_and_custom_policy(self):
        with pytest.raises(KeyError, match="unknown admission"):
            get_admission_policy("nope")
        with pytest.raises(ValueError, match="kwargs"):
            get_admission_policy(FIFOAdmission(), aging=3)

        @register_admission_policy("lifo-test")
        class LIFOAdmission(AdmissionPolicy):
            """Newest-first admission (test-only): take from the tail."""
            name = "lifo-test"

            def take(self, group, width, slot=None):
                return [group.queue.pop()
                        for _ in range(min(width, len(group.queue)))]

        try:
            assert isinstance(get_admission_policy("lifo-test"),
                              LIFOAdmission)
            stream = [ising_grid(6, 1.5, seed=s) for s in range(4)]
            engine = _lbp_engine(max_rounds=160)
            rep = serve_async(engine, stream, jax.random.key(0),
                              max_batch=2, chunk_rounds=32, slots=1,
                              prefetch=None, admission="lifo-test")
            ref = serve_async(engine, stream, jax.random.key(0),
                              max_batch=2, chunk_rounds=32, slots=1,
                              prefetch=None)
            for got, want in zip(rep.results, ref.results):
                _assert_bitwise(got, want)
        finally:
            ADMISSION_POLICIES.pop("lifo-test", None)

    def test_bpconfig_admission_plumbing(self):
        import json
        cfg = BPConfig(scheduler="lbp", eps=1e-5, max_rounds=160,
                       history=False, admission="windowed",
                       admission_kwargs={"window_s": 0.0})
        assert BPConfig.from_dict(
            json.loads(json.dumps(cfg.to_dict()))) == cfg
        with pytest.raises(ValueError, match="admission"):
            BPConfig(admission=FIFOAdmission()).to_dict()
        # the engine's config default drives the pipeline when no explicit
        # admission is passed
        rep = serve_async(BPEngine(cfg),
                          [ising_grid(6, 1.5, seed=0)], jax.random.key(0))
        assert rep.stats.policy == "windowed"

    def test_rounds_history(self):
        # The legacy nearest-neighbor predictor, pinned exactly.
        h = RoundsHistory(capacity=2, predictor="nearest")
        assert h.expect("k", 1.0) is None
        h.observe("k", 1.0, 100)
        h.observe("k", 5.0, 300)
        assert h.expect("k", 1.2) == 100
        assert h.expect("k", 4.0) == 300
        h.observe("k", 9.0, 900)        # capacity 2: oldest aged out
        assert h.expect("k", 1.2) == 300
        assert len(h) == 2
        with pytest.raises(ValueError):
            RoundsHistory(capacity=0)
        with pytest.raises(ValueError):
            RoundsHistory(predictor="magic")


class TestThreadedIngestion:
    """Satellite: ingest_threads decouples a blocking source from device
    dispatch via a bounded feeder queue; rid assignment and results match
    the unthreaded path item for item."""

    def test_blocking_iterator_served_bitwise(self):
        stream, _ = _straggler_stream()

        def blocking():
            for i, p in enumerate(stream):
                if i in (2, 5):
                    time.sleep(0.05)    # a stalling source
                yield p

        engine = _lbp_engine(max_rounds=320)
        kw = dict(max_batch=3, chunk_rounds=48, slots=2, prefetch=4)
        ref = serve_async(engine, iter(stream), jax.random.key(0), **kw)
        rep = serve_async(engine, blocking(), jax.random.key(0),
                          ingest_threads=2, ingest_queue=3, **kw)
        assert rep.stats.staged == len(stream)
        assert sorted(r.rid for r in rep.records) == list(range(len(stream)))
        by_rid = {r.rid: r for r in ref.records}
        for rec in rep.records:
            _assert_bitwise(rec.result, by_rid[rec.rid].result)

    def test_feeder_explicit_rids_and_duplicates(self):
        engine = _lbp_engine(max_rounds=128)
        rep = serve_async(engine,
                          iter([(5, ising_grid(6, 1.5, seed=0)),
                                (1, ising_grid(6, 1.5, seed=1))]),
                          jax.random.key(0), ingest_threads=1)
        assert sorted(r.rid for r in rep.records) == [1, 5]
        with pytest.raises(ValueError, match="duplicate"):
            serve_async(engine, iter([(3, ising_grid(6, 1.5, seed=0)),
                                      (3, ising_grid(6, 1.5, seed=1))]),
                        jax.random.key(0), ingest_threads=1)

    def test_feeder_propagates_source_errors_and_empty(self):
        engine = _lbp_engine(max_rounds=128)

        def broken():
            yield ising_grid(6, 1.5, seed=0)
            raise RuntimeError("source fell over")

        with pytest.raises(RuntimeError, match="fell over"):
            serve_async(engine, broken(), jax.random.key(0),
                        ingest_threads=2)
        empty = serve_async(engine, iter([]), jax.random.key(0),
                            ingest_threads=2)
        assert empty.records == []

    def test_admission_wait_reported_separately(self):
        """Small fix: percentile reporting splits admission wait from
        device residency instead of conflating them."""
        stream, _ = _straggler_stream()
        engine = _lbp_engine(max_rounds=128)
        rep = serve_async(engine, iter(stream), jax.random.key(0),
                          max_batch=4, chunk_rounds=32)
        total = rep.latency_percentiles((50,))
        wait = rep.latency_percentiles((50,), field="admission")
        svc = rep.latency_percentiles((50,), field="service")
        assert wait["p50"] >= 0 and svc["p50"] > 0
        for rec in rep.records:
            assert rec.latency_s == pytest.approx(
                rec.queue_s + rec.service_s)
        assert total["p50"] <= wait["p50"] + svc["p50"] + 1e-6 \
            or total["p50"] >= 0     # percentiles of sums need not add up
        with pytest.raises(KeyError):
            rep.latency_percentiles((50,), field="bogus")

    def test_feeder_stops_when_generator_abandoned(self):
        """Closing/abandoning the serve generator must stop the feeder:
        the source stops being consumed instead of leaking daemon threads
        that pull (and drop) requests forever."""
        import threading
        pulled = []

        def src():
            for s in range(200):
                pulled.append(s)
                yield ising_grid(6, 1.5, seed=s % 4)

        engine = _lbp_engine(max_rounds=128)
        pipe = ServingPipeline(engine, jax.random.key(0), max_batch=2,
                               chunk_rounds=32, prefetch=2,
                               ingest_threads=2, ingest_queue=2)
        before = threading.active_count()
        gen = pipe.serve(src())
        next(gen)               # at least one record served
        gen.close()             # abandon -> finally -> feeder.close()
        time.sleep(0.3)         # workers notice the stop flag
        n = len(pulled)
        assert n < 200          # bounded queue kept the pull lazy
        time.sleep(0.3)
        assert len(pulled) == n  # source no longer being consumed
        assert threading.active_count() <= before

    def test_policy_instance_cannot_be_shared_across_pipelines(self):
        """A policy instance holds pipeline-coupled state; rebinding to a
        second pipeline must refuse loudly instead of silently reading the
        wrong pipeline's groups."""
        from repro.core import WindowedAdmission
        pol = WindowedAdmission(window_s=0.5)
        engine = _lbp_engine(max_rounds=128)
        ServingPipeline(engine, jax.random.key(0), admission=pol)
        with pytest.raises(ValueError, match="already bound"):
            ServingPipeline(engine, jax.random.key(1), admission=pol)


class TestPipelineLifecycle:
    """Satellite: explicit close()/context-manager shutdown. Owners that
    hold the pipeline (the router tier's replicas) must be able to
    guarantee no feeder thread survives teardown, even when the serve
    generator was abandoned mid-yield."""

    def test_close_joins_feeder_threads_and_refuses_serve(self):
        import threading
        engine = _lbp_engine(max_rounds=64)

        def src():
            for s in range(100):
                yield ising_grid(6, 1.5, seed=s % 4)

        pipe = ServingPipeline(engine, jax.random.key(0), max_batch=2,
                               chunk_rounds=16, prefetch=2,
                               ingest_threads=2, ingest_queue=2)
        before = threading.active_count()
        gen = pipe.serve(src())
        next(gen)               # feeder threads live now
        assert threading.active_count() > before
        pipe.close()            # owner-side shutdown, generator still open
        deadline = time.time() + 5
        while threading.active_count() > before and time.time() < deadline:
            time.sleep(0.02)
        assert threading.active_count() <= before   # close() joined them
        with pytest.raises(ValueError, match="closed"):
            next(pipe.serve(iter([])))
        pipe.close()            # idempotent

    def test_context_manager_closes_on_exit(self):
        import threading
        engine = _lbp_engine(max_rounds=64)
        stream = [ising_grid(6, 1.5, seed=s) for s in range(4)]
        before = threading.active_count()
        with ServingPipeline(engine, jax.random.key(0), max_batch=2,
                             chunk_rounds=16, ingest_threads=1) as pipe:
            recs = list(pipe.serve(iter(stream)))
        assert len(recs) == len(stream)
        deadline = time.time() + 5
        while threading.active_count() > before and time.time() < deadline:
            time.sleep(0.02)
        assert threading.active_count() <= before
        with pytest.raises(ValueError, match="closed"):
            next(pipe.serve(iter(stream)))
