"""Sharding resolver rules: divisibility fallbacks, megatron roles, cache
and batch specs. Pure metadata tests -- no multi-device needed."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get
from repro.launch.sharding import (_param_pspec, batch_shardings,
                                   cache_shardings, param_shardings)
from repro.models import build_model


class FakeEntry:
    def __init__(self, key):
        self.key = key


def spec_of(name, shape, mp=16, stacked=False):
    leaf = jax.ShapeDtypeStruct(shape, jnp.float32)
    return _param_pspec((FakeEntry(name),), leaf, mp, stacked)


class TestParamRules:
    def test_column_parallel(self):
        assert spec_of("wq", (4096, 2048)) == P(None, "model")

    def test_row_parallel(self):
        assert spec_of("wo", (2048, 4096)) == P("model", None)

    def test_divisibility_fallback(self):
        # output dim 75 not divisible by 16 -> replicate
        assert spec_of("wq", (128, 75)) == P(None, None)

    def test_embedding_vocab_sharded(self):
        assert spec_of("table", (152064, 2560)) == P("model", None)

    def test_moe_expert_ff_sharded(self):
        assert spec_of("w_in", (40, 1536, 512)) == P(None, None, "model")
        assert spec_of("w_out", (40, 512, 1536)) == P(None, "model", None)

    def test_stacked_leading_layer_axis(self):
        # (L, d, out): leading scan axis never sharded
        assert spec_of("wq", (36, 2560, 4096), stacked=True) == \
            P(None, None, "model")

    def test_norms_replicated(self):
        assert spec_of("ln1", (2560,)) == P(None)


class TestTreeShardings:
    @pytest.mark.parametrize("arch", ["qwen3_4b", "granite_moe_3b_a800m",
                                      "mamba2_130m", "whisper_medium"])
    def test_param_shardings_cover_tree(self, arch):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        model = build_model(get(arch))
        specs = model.param_specs()
        sh = param_shardings(mesh, specs)
        assert jax.tree.structure(sh) == jax.tree.structure(specs)

    def test_cache_seq_sharded_on_model(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        model = build_model(get("qwen3_4b"))
        cache = model.init_cache_specs(128, 32768)
        sh = cache_shardings(mesh, cache)
        k_spec = sh["main"]["k"].spec
        assert k_spec[2] == "model"        # sequence axis (flash-decoding)

    def test_batch_replicates_when_indivisible(self):
        # B=1 (long_500k) cannot shard over the data axis -> replicate.
        # AbstractMesh: sharding metadata without needing 2 real devices.
        try:
            mesh = jax.sharding.AbstractMesh((2, 1), ("data", "model"))
        except TypeError:
            pytest.skip("AbstractMesh(axis_sizes, axis_names) needs newer jax")
        sh = batch_shardings(
            mesh, {"tokens": jax.ShapeDtypeStruct((1, 1), jnp.int32)})
        assert sh["tokens"].spec == P(None, None)
