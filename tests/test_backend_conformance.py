"""Cross-backend differential conformance: every registered update backend
x every registered scheduler, pinned against the reference semantics.

The registries are enumerated dynamically (``list_backends()`` x
``list_schedulers()``), so a newly registered backend or scheduler is
conformance-tested by existence -- forgetting to test it is impossible.
Per-pair runs rotate through a mixed corpus (ising grid, chain, LDPC
decoder graph, stereo MRF) chosen so every scheduler converges on every
graph; across the matrix every graph kind meets every backend.

Oracles and tolerances are per-backend:

- ``ref`` IS the sum-product reference -- conformance is bitwise.
- ``pallas`` / ``triton`` (interpret mode) reassociate reductions inside
  the fused kernel, so beliefs match to ~1e-4 and round counts to a small
  drift (residual-threshold crossings can shift by ulps).
- ``sharded`` adds a cross-device edge split on top -- 5e-3.
- ``maxprod`` is compared against ``triton(semiring="max")`` -- a true
  differential pair (two independent implementations of the max semiring);
  max reductions are order-exact so agreement is near-bitwise.

Also here: the chunked-resume bitwise contract per backend (N rounds via
``step`` == N rounds in one ``run``), serving-stack parity for the triton
backend, and hypothesis fuzz of the kernel pair over degenerate shapes
(S=2, non-power-of-two S, E=1, E below one block, all-masked edges).
``hypothesis`` is an optional extra: without it the fuzz class skips and
the explicit degenerate-shape tests still run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                       # degrade: property tests skip
    def given(*_a, **_k):
        return lambda f: f

    def settings(*_a, **_k):
        return lambda f: f

    class st:  # noqa: N801 - stand-in namespace, never executed
        integers = floats = booleans = staticmethod(lambda *a, **k: None)

from repro.core import BPConfig, BPEngine
from repro.core import messages as M
from repro.core.graph import NEG_INF
from repro.core.schedulers import list_schedulers
from repro.kernels.message_update import fused_update_t
from repro.kernels.ops import list_backends, make_triton_update
from repro.kernels.ref import fused_update_e_ref, fused_update_t_ref
from repro.kernels.triton_update import fused_update_e
from repro.pgm import chain_graph, ising_grid, ldpc_graph, stereo_graph

EPS, MAX_ROUNDS = 1e-3, 2000

#: graph kind -> factory; all six schedulers converge on each (pinned by
#: test_corpus_converges_everywhere below).
CORPUS = {
    "ising": lambda: ising_grid(5, 1.5, seed=0),
    "chain": lambda: chain_graph(30, seed=1),
    "ldpc": lambda: ldpc_graph(seed=0, n=24, dv=3, dc=6, snr_db=3.0),
    "stereo": lambda: stereo_graph(seed=0, height=4, width=5, n_disp=4),
}

#: backend -> (belief atol, rounds must match exactly). The "trajectory"
#: claim: exact backends reproduce the reference round-for-round; kernel
#: backends may shift threshold crossings by reassociation ulps.
TOLERANCE = {
    "ref": (0.0, True),
    "maxprod": (1e-6, True),
    "pallas": (1e-4, False),
    "triton": (1e-4, False),
    "sharded": (5e-3, False),
}

BACKENDS = list_backends()
SCHEDULERS = list_schedulers()

_pgm_cache = {}
_oracle_cache = {}


def corpus_pgm(gname):
    if gname not in _pgm_cache:
        _pgm_cache[gname] = CORPUS[gname]()
    return _pgm_cache[gname]


def _run(backend, scheduler, gname):
    eng = BPEngine(BPConfig(scheduler=scheduler, eps=EPS,
                            max_rounds=MAX_ROUNDS, history=False,
                            backend=backend))
    return eng.run(corpus_pgm(gname), jax.random.key(0))


def oracle_result(scheduler, gname, semiring):
    """Reference trajectory for (scheduler, graph): the pure-jnp update of
    the matching semiring. Cached -- many matrix cells share an oracle."""
    key = (scheduler, gname, semiring)
    if key not in _oracle_cache:
        backend = "ref" if semiring == "sum" else \
            make_triton_update(True, semiring="max")
        _oracle_cache[key] = _run(backend, scheduler, gname)
    return _oracle_cache[key]


class TestBackendSchedulerMatrix:
    """Every (backend, scheduler) pair runs a corpus graph (rotating, so
    all four graph kinds are exercised against every backend) and must
    reproduce the matching-semiring reference beliefs and trajectory."""

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("scheduler", SCHEDULERS)
    def test_backend_matches_reference(self, backend, scheduler):
        semiring = "max" if backend == "maxprod" else "sum"
        # Max-product oscillates forever on the loopy ising grid (ties in
        # the max make the fixed point unstable) -- a semiring property,
        # not a backend bug -- so the max rotation skips that graph.
        gnames = [g for g in CORPUS if g != "ising"] \
            if semiring == "max" else list(CORPUS)
        gname = gnames[(BACKENDS.index(backend)
                        + SCHEDULERS.index(scheduler)) % len(gnames)]
        res = _run(backend, scheduler, gname)
        ref = oracle_result(scheduler, gname, semiring)
        atol, exact_rounds = TOLERANCE[backend]
        assert bool(res.converged) and bool(ref.converged)
        if exact_rounds:
            assert int(res.rounds) == int(ref.rounds)
        else:
            drift = max(10, int(ref.rounds) // 5)
            assert abs(int(res.rounds) - int(ref.rounds)) <= drift
        if atol == 0.0:
            np.testing.assert_array_equal(np.asarray(res.logm),
                                          np.asarray(ref.logm))
            np.testing.assert_array_equal(np.asarray(res.beliefs),
                                          np.asarray(ref.beliefs))
        else:
            np.testing.assert_allclose(np.asarray(res.beliefs),
                                       np.asarray(ref.beliefs), atol=atol)

    def test_matrix_is_complete(self):
        """The enumeration really covers the live registries (a regression
        here means a backend/scheduler was registered but not conformed)."""
        assert set(BACKENDS) >= {"ref", "maxprod", "pallas", "triton",
                                 "sharded"}
        assert set(SCHEDULERS) >= {"lbp", "rbp", "rlx", "rlxtree", "rnbp",
                                   "rs"}
        assert set(TOLERANCE) >= set(BACKENDS)

    def test_corpus_converges_everywhere(self):
        """Corpus admission gate: all schedulers converge on all graphs
        under the reference backend (a corpus graph that stops converging
        would silently weaken every matrix cell)."""
        for gname in CORPUS:
            for scheduler in SCHEDULERS:
                res = oracle_result(scheduler, gname, "sum")
                assert bool(res.converged), (gname, scheduler)


class TestChunkedResumePerBackend:
    """The engine's resume contract, per backend: N rounds via repeated
    7-round ``step`` chunks are bit-identical to N rounds in one ``run``."""

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_chunked_equals_monolithic(self, backend):
        gname = "ldpc" if backend == "maxprod" else "ising"
        pgm = corpus_pgm(gname)
        eng = BPEngine(BPConfig(scheduler="rs", eps=EPS,
                                max_rounds=MAX_ROUNDS, backend=backend))
        mono = eng.run(pgm, jax.random.key(7))
        state = eng.init(pgm, jax.random.key(7))
        while not eng.finished(state):
            state = eng.step(state, chunk_rounds=7)
        chunked = eng.result(state)
        np.testing.assert_array_equal(np.asarray(mono.logm),
                                      np.asarray(chunked.logm))
        np.testing.assert_array_equal(np.asarray(mono.beliefs),
                                      np.asarray(chunked.beliefs))
        assert int(mono.rounds) == int(chunked.rounds)
        assert int(mono.updates) == int(chunked.updates)


class TestTritonServingStack:
    """``BPConfig(backend="triton")`` through the serving layers."""

    def _stream(self):
        return [ising_grid(5, 1.5, seed=s) for s in range(4)]

    def test_serve_matches_ref_backend(self):
        rng = jax.random.key(3)
        outs = {}
        for backend in ("ref", "triton"):
            eng = BPEngine(BPConfig(scheduler="rbp", eps=EPS,
                                    max_rounds=MAX_ROUNDS, history=False,
                                    backend=backend))
            outs[backend] = eng.serve(self._stream(), rng).results
        for r_ref, r_tri in zip(outs["ref"], outs["triton"]):
            assert bool(r_ref.converged) and bool(r_tri.converged)
            np.testing.assert_allclose(np.asarray(r_tri.beliefs),
                                       np.asarray(r_ref.beliefs), atol=1e-4)

    def test_native_batch_backend_matches_folded(self):
        """The natively batched triton entry (batch axis folded into the
        kernel's edge grid) is bitwise-equal to the engine's default fold
        through the single-graph backend."""
        import dataclasses
        rng = jax.random.key(5)
        base = BPConfig(scheduler="rnbp", eps=EPS, max_rounds=MAX_ROUNDS,
                        history=False, backend="triton")
        folded = BPEngine(base).run_many(self._stream(), rng)
        native = BPEngine(dataclasses.replace(base, batch_backend="triton")) \
            .run_many(self._stream(), rng)
        for rf, rn in zip(folded, native):
            np.testing.assert_array_equal(np.asarray(rf.logm),
                                          np.asarray(rn.logm))
            assert int(rf.rounds) == int(rn.rounds)


def _edge_major_operands(rng, e, s, *, all_masked_frac=0.0):
    logpsi = rng.standard_normal((e, s, s)).astype(np.float32)
    pre = rng.standard_normal((e, s)).astype(np.float32)
    nvalid = rng.integers(1, s + 1, size=e)
    dmask = (np.arange(s)[None, :] < nvalid[:, None])
    if all_masked_frac:
        dmask[rng.random(e) < all_masked_frac] = False
    logm = np.where(dmask, rng.standard_normal((e, s)), NEG_INF)
    return (jnp.asarray(logpsi), jnp.asarray(pre),
            jnp.asarray(logm.astype(np.float32)), jnp.asarray(dmask))


class TestDegenerateShapes:
    """Explicit (always-run) pins on the shapes the padding logic must get
    right: single edge, sub-block edge counts, non-power-of-two states."""

    @pytest.mark.parametrize("e,s", [(1, 2), (3, 2), (17, 5), (100, 17),
                                     (128, 2), (130, 4)])
    @pytest.mark.parametrize("semiring", ["sum", "max"])
    def test_gpu_kernel_vs_oracle(self, e, s, semiring):
        rng = np.random.default_rng(e * 100 + s)
        ops = _edge_major_operands(rng, e, s)
        new_k, r_k = fused_update_e(*ops, semiring=semiring, interpret=True)
        new_r, r_r = fused_update_e_ref(*ops, semiring=semiring)
        assert new_k.shape == (e, s)
        dmask = np.asarray(ops[3])
        np.testing.assert_allclose(
            np.where(dmask, np.asarray(new_k), 0.0),
            np.where(dmask, np.asarray(new_r), 0.0), atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(r_k), np.asarray(r_r),
                                   atol=1e-5, rtol=1e-5)

    @pytest.mark.parametrize("semiring", ["sum", "max"])
    def test_all_masked_edges_inert(self, semiring):
        """Fully masked edges (the padded-lane contract): NEG_INF messages
        and exactly zero residual, both semirings."""
        rng = np.random.default_rng(0)
        ops = _edge_major_operands(rng, 40, 4, all_masked_frac=0.5)
        dead = ~np.asarray(ops[3]).any(axis=1)
        assert dead.any()          # the fraction actually produced some
        new, r = fused_update_e(*ops, semiring=semiring, interpret=True)
        new, r = np.asarray(new), np.asarray(r)
        assert np.all(new[dead] == np.float32(NEG_INF))
        assert np.all(r[dead] == 0.0)

    def test_gpu_vs_tpu_kernel_differential(self):
        """The two kernels are layout transposes of the same math: same
        operands (transposed) must give the same messages and residuals."""
        rng = np.random.default_rng(42)
        e, s = 200, 7
        logpsi, pre, logm, dmask = _edge_major_operands(rng, e, s)
        new_e, r_e = fused_update_e(logpsi, pre, logm, dmask, interpret=True)
        new_t, r_t = fused_update_t(
            jnp.transpose(logpsi, (1, 2, 0)), pre.T, logm.T, dmask.T,
            interpret=True)
        np.testing.assert_allclose(np.asarray(new_e),
                                   np.asarray(new_t).T, atol=1e-6)
        np.testing.assert_allclose(np.asarray(r_e), np.asarray(r_t),
                                   atol=1e-6)


class TestKernelFuzz:
    """Hypothesis sweep of the (shape, seed) space for both kernels and
    both semirings against the pure-jnp oracles."""

    @pytest.fixture(autouse=True, scope="class")
    def _require_hypothesis(self):
        pytest.importorskip("hypothesis")

    @settings(max_examples=30, deadline=None)
    @given(s=st.integers(2, 17), e=st.integers(1, 200),
           seed=st.integers(0, 2**16), maxprod=st.booleans())
    def test_gpu_kernel_fuzz(self, s, e, seed, maxprod):
        rng = np.random.default_rng(seed)
        semiring = "max" if maxprod else "sum"
        ops = _edge_major_operands(rng, e, s, all_masked_frac=0.1)
        new_k, r_k = fused_update_e(*ops, semiring=semiring, interpret=True)
        new_r, r_r = fused_update_e_ref(*ops, semiring=semiring)
        dmask = np.asarray(ops[3])
        np.testing.assert_allclose(
            np.where(dmask, np.asarray(new_k), 0.0),
            np.where(dmask, np.asarray(new_r), 0.0), atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(r_k), np.asarray(r_r),
                                   atol=1e-5, rtol=1e-5)
        assert np.all(np.asarray(new_k)[~dmask] == np.float32(NEG_INF))

    @settings(max_examples=20, deadline=None)
    @given(s=st.integers(2, 17), e=st.integers(1, 200),
           seed=st.integers(0, 2**16))
    def test_tpu_kernel_fuzz(self, s, e, seed):
        rng = np.random.default_rng(seed)
        logpsi, pre, logm, dmask = _edge_major_operands(rng, e, s)
        ops_t = (jnp.transpose(logpsi, (1, 2, 0)), pre.T, logm.T, dmask.T)
        new_k, r_k = fused_update_t(*ops_t, interpret=True)
        new_r, r_r = fused_update_t_ref(*ops_t)
        dm = np.asarray(dmask).T
        np.testing.assert_allclose(
            np.where(dm, np.asarray(new_k), 0.0),
            np.where(dm, np.asarray(new_r), 0.0), atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(r_k), np.asarray(r_r),
                                   atol=1e-5, rtol=1e-5)
