"""Router/replica serving tier (repro.serve): the determinism pin vs solo
``serve_async`` shares, the routing-policy registry (fourth family), work
stealing's result invariance, and clean thread teardown.

The load-bearing invariant: a request's trajectory depends only on
(rid, padded shape) -- every replica folds the same base rng and pads
online to the same ``bucket_shape`` ceilings -- so *which replica* serves a
request (routing policy, work stealing) can never change a result bit."""

import threading
import time

import jax
import numpy as np
import pytest

from repro.core import BPConfig, BPEngine, RoundsHistory, serve_async
from repro.core.batch import bucket_shape
from repro.pgm import chain_graph, ising_grid
from repro.serve import (KindAffinityRouting, LeastLoadedRouting,
                         ROUTING_POLICIES, ReplicaLoad, RoundRobinRouting,
                         Router, RoutingPolicy, get_routing_policy,
                         list_routing_policies, register_routing_policy,
                         serve_routed)
from repro.serve.replica import _Inbox, _Request

CFG = BPConfig(scheduler="lbp", eps=1e-5, max_rounds=160, history=False)
KW = dict(max_batch=2, chunk_rounds=16)


@pytest.fixture(scope="module")
def engines():
    # One engine per replica, shared across tests so jit caches warm once.
    return [BPEngine(CFG), BPEngine(CFG)]


def _mixed_stream():
    # Two shape families; the C=3.0 grids stall to max_rounds (stragglers).
    return [ising_grid(6, 1.5, seed=1), chain_graph(30, seed=2),
            ising_grid(6, 2.0, seed=3), chain_graph(34, seed=4),
            ising_grid(6, 3.0, seed=5), chain_graph(30, seed=6),
            ising_grid(6, 1.8, seed=7)]


def _assert_bitwise(got, want):
    assert int(got.rounds) == int(want.rounds)
    assert int(got.updates) == int(want.updates)
    np.testing.assert_array_equal(np.asarray(got.logm), np.asarray(want.logm))


def _wait_threads(baseline, timeout=10.0):
    deadline = time.time() + timeout
    while threading.active_count() > baseline and time.time() < deadline:
        time.sleep(0.02)
    return threading.active_count()


class TestDeterminismPin:
    """Acceptance: round_robin + steal=False is bitwise-identical per
    request to running each replica's share through serve_async solo."""

    def test_round_robin_no_steal_matches_solo_shares(self, engines):
        stream = _mixed_stream()
        res = serve_routed(engines, iter(stream), jax.random.key(0),
                           routing="round_robin", steal=False, **KW)
        by_rid = {r.rid: r.result for r in res.records}
        assert sorted(by_rid) == list(range(len(stream)))
        for k in range(len(engines)):
            share = [(i, p) for i, p in enumerate(stream)
                     if i % len(engines) == k]
            # iter(): the online bucket_shape path, same as the replicas.
            solo = serve_async(engines[0], iter(share), jax.random.key(0),
                               **KW)
            assert solo.records, "share must not be empty"
            for rec in solo.records:
                _assert_bitwise(by_rid[rec.rid], rec.result)

    def test_load_aware_routing_and_stealing_results_invariant(self, engines):
        stream = _mixed_stream()
        want = serve_async(engines[0], iter(stream), jax.random.key(0),
                           **KW).records
        by_rid = {r.rid: r.result for r in want}
        for routing, steal in (("least_loaded", True),
                               ("kind_affinity", False)):
            res = serve_routed(engines, iter(stream), jax.random.key(0),
                               routing=routing, steal=steal,
                               low_watermark=2, prefetch=2, **KW)
            assert len(res.records) == len(stream)
            for rec in res.records:
                _assert_bitwise(rec.result, by_rid[rec.rid])
            if routing == "kind_affinity":
                # sticky placement: every kind on exactly one replica
                homes = {}
                for rec in res.records:
                    homes.setdefault(rec.kind, set()).add(rec.replica)
                assert all(len(v) == 1 for v in homes.values()), homes


class TestRoutingRegistry:
    """Satellite: fourth registry family -- uniform error format, duplicate
    rejection, custom-policy registration."""

    def test_builtins_and_uniform_unknown_name_error(self):
        assert set(list_routing_policies()) >= {"round_robin", "least_loaded",
                                                "kind_affinity"}
        # Same KeyError shape as the scheduler/backend/admission families
        # (cross-family uniformity is asserted in test_engine.py).
        with pytest.raises(KeyError,
                           match=r"unknown routing policy 'nope'; "
                                 r"registered: \["):
            get_routing_policy("nope")

    def test_duplicate_registration_raises(self):
        with pytest.raises(ValueError, match="duplicate routing policy"):
            register_routing_policy("round_robin")(RoundRobinRouting)
        cls = ROUTING_POLICIES["round_robin"]
        assert register_routing_policy(
            "round_robin", overwrite=True)(cls) is cls

    def test_custom_policy_registration_drives_router(self, engines):
        @register_routing_policy("test_always_last", overwrite=True)
        class AlwaysLast(RoutingPolicy):
            name = "test_always_last"

            def pick(self, rid, kind, loads):
                return len(loads) - 1

        stream = [ising_grid(6, 1.5, seed=s) for s in range(3)]
        res = serve_routed(engines, iter(stream), jax.random.key(0),
                           routing="test_always_last", **KW)
        assert all(rec.replica == len(engines) - 1 for rec in res.records)
        assert res.stats.policy == "test_always_last"
        assert res.stats.routed == [0, len(stream)]

    def test_policy_instance_is_per_router(self, engines):
        pol = RoundRobinRouting()
        Router(engines, jax.random.key(0), routing=pol, **KW).close()
        with pytest.raises(ValueError, match="already bound"):
            Router(engines, jax.random.key(0), routing=pol, **KW)
        with pytest.raises(ValueError, match="instance"):
            get_routing_policy(RoundRobinRouting(), spread=2)


class TestPolicyPlacement:
    """Pure pick() logic against synthetic load snapshots."""

    @staticmethod
    def _loads(*weights):
        return [ReplicaLoad(replica=i, inbox=0, staged=0, in_flight=0,
                            effort=w) for i, w in enumerate(weights)]

    def test_round_robin_cycles(self):
        pol = RoundRobinRouting()
        loads = self._loads(9.0, 0.0, 5.0)
        assert [pol.pick(i, (), loads) for i in range(6)] == [0, 1, 2, 0, 1, 2]

    def test_least_loaded_minimizes_weight_ties_to_lowest(self):
        pol = LeastLoadedRouting()
        assert pol.pick(0, (), self._loads(3.0, 1.0, 2.0)) == 1
        assert pol.pick(1, (), self._loads(2.0, 2.0, 5.0)) == 0

    def test_kind_affinity_sticky_and_spread(self):
        pol = KindAffinityRouting()
        a, b = ("a",), ("b",)
        assert pol.pick(0, a, self._loads(5.0, 1.0)) == 1
        # sticky even after the load situation flips
        assert pol.pick(1, a, self._loads(0.0, 9.0)) == 1
        assert pol.pick(2, b, self._loads(0.0, 9.0)) == 0
        capped = KindAffinityRouting(spread=1)
        assert capped.pick(0, a, self._loads(1.0, 2.0)) == 0
        # replica 0 is full (spread=1): new kind overflows to least-loaded
        # without sticking
        assert capped.pick(1, b, self._loads(0.0, 9.0)) == 0
        assert capped.pick(2, b, self._loads(9.0, 0.0)) == 1


class TestWorkStealing:
    """Stealing rebalances a skewed stream without changing any result."""

    def test_hotspot_steal_triggers_and_results_invariant(self, engines):
        # Custom skew policy: tiny share on replica 0, heavy hotspot on
        # replica 1 -- replica 0 drains, then must steal the stragglers.
        @register_routing_policy("test_hotspot", overwrite=True)
        class Hotspot(RoutingPolicy):
            name = "test_hotspot"

            def __init__(self):
                super().__init__()
                self._n = 0

            def pick(self, rid, kind, loads):
                i = 0 if self._n < 2 else 1
                self._n += 1
                return i

        stream = ([ising_grid(6, 1.5, seed=s) for s in range(2)]
                  + [ising_grid(6, 3.0, seed=100 + s) for s in range(10)])
        want = {r.rid: r.result
                for r in serve_async(engines[0], iter(stream),
                                     jax.random.key(0), **KW).records}
        res = serve_routed(engines, iter(stream), jax.random.key(0),
                           routing="test_hotspot", steal=True,
                           steal_batch=2, low_watermark=2, prefetch=2,
                           ingest_queue=1, **KW)
        assert res.stats.stolen > 0, "skewed stream must trigger stealing"
        assert res.stats.steals > 0
        flagged = [rec for rec in res.records if rec.stolen]
        assert len(flagged) == res.stats.stolen
        # stolen work really ran on the thief
        assert any(rec.replica == 0 for rec in flagged)
        for rec in res.records:
            _assert_bitwise(rec.result, want[rec.rid])

    def test_inbox_steal_mechanics(self):
        inbox = _Inbox(capacity=8)
        reqs = [_Request(rid=i, pgm=None, kind=("k",), t_route=0.0)
                for i in range(5)]
        for r in reqs:
            inbox.put(r)
        # steal takes from the tail, oldest-first order preserved, victim
        # keeps at least `leave`
        got = inbox.steal(10, leave=2)
        assert [r.rid for r in got] == [2, 3, 4]
        assert len(inbox) == 2
        assert inbox.pop(timeout=0.01).rid == 0
        inbox.finish()
        with pytest.raises(ValueError, match="closed"):
            inbox.put(reqs[0])
        inbox.put(reqs[2], force=True)      # steal transplant still lands
        assert inbox.pop(timeout=0.01).rid == 1
        assert inbox.pop(timeout=0.01).rid == 2
        assert inbox.pop(timeout=0.01) is not None   # _CLOSED sentinel
        inbox.close()
        assert len(inbox) == 0


class TestTierLifecycle:
    """Satellite: replica teardown must not leak threads (tier-1 runs in
    one process; every serve must return the thread count to baseline)."""

    def test_no_thread_leak_after_serve(self, engines):
        stream = [ising_grid(6, 1.5, seed=s) for s in range(4)]
        baseline = threading.active_count()
        res = serve_routed(engines, iter(stream), jax.random.key(0),
                           routing="round_robin", **KW)
        assert len(res.records) == len(stream)
        assert _wait_threads(baseline) <= baseline

    def test_close_tears_down_abandoned_router(self, engines):
        stream = (ising_grid(6, 3.0, seed=s) for s in range(12))
        baseline = threading.active_count()
        router = Router(engines, jax.random.key(1), routing="round_robin",
                        **KW)
        gen = router.serve(stream)
        next(gen)                   # at least one record served
        router.close()              # abandon mid-stream
        gen.close()
        assert _wait_threads(baseline) <= baseline
        with pytest.raises(ValueError, match="one-shot|closed"):
            next(router.serve(iter([])))

    def test_router_one_shot_and_duplicate_rids(self, engines):
        router = Router(engines, jax.random.key(0), **KW)
        list(router.serve([ising_grid(6, 1.5, seed=0)]))
        with pytest.raises(ValueError, match="one-shot"):
            next(router.serve([ising_grid(6, 1.5, seed=1)]))
        dup = [(0, ising_grid(6, 1.5, seed=0)), (0, ising_grid(6, 1.5,
                                                               seed=1))]
        with pytest.raises(ValueError, match="duplicate request id"):
            list(Router(engines, jax.random.key(0), **KW).serve(iter(dup)))

    def test_engine_arg_validation(self):
        with pytest.raises(ValueError, match="replicas"):
            Router([BPEngine(CFG)], jax.random.key(0), replicas=3)
        with pytest.raises(TypeError, match="engine"):
            Router(object(), jax.random.key(0))
        with pytest.raises(ValueError, match="prefetch"):
            Router(CFG, jax.random.key(0), replicas=1, prefetch=None)


class TestObservability:
    """Replica attribution, merged percentiles, pooled effort history."""

    def test_attribution_percentiles_shared_history(self, engines):
        stream = _mixed_stream()
        hist = RoundsHistory()
        res = serve_routed(engines, iter(stream), jax.random.key(0),
                           routing="least_loaded", history=hist, **KW)
        assert {rec.replica for rec in res.records} <= {0, 1}
        assert sum(len(v) for v in res.by_replica().values()) == len(stream)
        assert sum(res.stats.routed) == len(stream)
        pct = res.latency_percentiles()
        assert set(pct) == {"p50", "p90", "p99"}
        assert all(np.isfinite(v) for v in pct.values())
        svc = res.latency_percentiles(field="service")
        assert svc["p99"] <= pct["p99"] + 1e-6   # service is a sub-interval
        # effort observations pooled tier-wide under the namespaced kind
        kind = bucket_shape(stream[0], 2.0)
        assert hist.mean(("routed", kind)) is not None
        assert res.device_sweeps >= res.useful_sweeps > 0
        assert len(res.results) == len(stream)
        assert all(r is not None for r in res.results)
