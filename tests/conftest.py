"""Shared fixtures. NOTE: no XLA_FLAGS here -- smoke tests and benches see
the real single CPU device; only launch/dryrun.py forces 512 devices.
Multi-device shard_map tests spawn a subprocess with the flag instead."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
