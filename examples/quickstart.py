"""Quickstart: Randomized Belief Propagation on an Ising grid.

Reproduces the paper's core result in miniature: on a hard Ising grid,
synchronous (Loopy) BP stalls while RnBP's randomized frontier converges,
at the same per-round cost and with no sort-and-select overhead.

Everything routes through the unified engine: one serializable ``BPConfig``
(scheduler spec string + kwargs) drives ``BPEngine.run``.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import numpy as np

from repro.core import BPConfig, BPEngine

from repro.pgm import ising_grid


def main():
    # C controls difficulty (paper SSIII-C); this instance is in the regime
    # where synchronous LBP oscillates forever but randomized scheduling
    # converges (paper Fig 4b)
    pgm = ising_grid(40, C=2.5, seed=2)
    print(f"Ising 40x40, C=2.5: {pgm.n_real_vertices} vertices, "
          f"{pgm.n_real_edges} directed edges")

    base = BPConfig(eps=1e-3, max_rounds=8000)
    for name, spec, kwargs in [
        ("LBP  (all messages)      ", "lbp", {}),
        ("RBP  (top-k, p=1/128)    ", "rbp", {"p": 1 / 128}),
        ("RnBP (random, LowP=0.4)  ", "rnbp", {"low_p": 0.4}),
        ("RnBP (random, LowP=0.1)  ", "rnbp", {"low_p": 0.1}),
    ]:
        engine = BPEngine(base, scheduler=spec, scheduler_kwargs=kwargs)
        t0 = time.perf_counter()
        res = engine.run(pgm, jax.random.key(0))
        jax.block_until_ready(res.logm)
        dt = time.perf_counter() - t0
        status = "converged" if bool(res.converged) else "STALLED  "
        print(f"{name} {status} rounds={int(res.rounds):5d} "
              f"committed-updates={int(res.updates):10d} "
              f"wall={dt:6.2f}s")

    engine = BPEngine(base, scheduler="rnbp", scheduler_kwargs={"low_p": 0.4})
    res = engine.run(pgm, jax.random.key(0))
    beliefs = np.exp(np.asarray(res.beliefs))[:pgm.n_real_vertices]
    print("\nfirst 5 marginals P(x_i = 1):", np.round(beliefs[:5, 1], 4))


if __name__ == "__main__":
    main()
