"""Quickstart: Randomized Belief Propagation on an Ising grid.

Reproduces the paper's core result in miniature: on a hard Ising grid,
synchronous (Loopy) BP stalls while RnBP's randomized frontier converges,
at the same per-round cost and with no sort-and-select overhead.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import numpy as np

from repro.core import LBP, RBP, RnBP, run_bp
from repro.pgm import ising_grid


def main():
    # C controls difficulty (paper SSIII-C); this instance is in the regime
    # where synchronous LBP oscillates forever but randomized scheduling
    # converges (paper Fig 4b)
    pgm = ising_grid(40, C=2.5, seed=2)
    print(f"Ising 40x40, C=2.5: {pgm.n_real_vertices} vertices, "
          f"{pgm.n_real_edges} directed edges")

    for name, sched in [
        ("LBP  (all messages)      ", LBP()),
        ("RBP  (top-k, p=1/128)    ", RBP(p=1 / 128)),
        ("RnBP (random, LowP=0.4)  ", RnBP(low_p=0.4)),
        ("RnBP (random, LowP=0.1)  ", RnBP(low_p=0.1)),
    ]:
        t0 = time.perf_counter()
        res = run_bp(pgm, sched, jax.random.key(0), eps=1e-3,
                     max_rounds=8000)
        jax.block_until_ready(res.logm)
        dt = time.perf_counter() - t0
        status = "converged" if bool(res.converged) else "STALLED  "
        print(f"{name} {status} rounds={int(res.rounds):5d} "
              f"committed-updates={float(res.updates):10.0f} "
              f"wall={dt:6.2f}s")

    res = run_bp(pgm, RnBP(low_p=0.4), jax.random.key(0), eps=1e-3,
                 max_rounds=8000)
    beliefs = np.exp(np.asarray(res.beliefs))[:pgm.n_real_vertices]
    print("\nfirst 5 marginals P(x_i = 1):", np.round(beliefs[:5, 1], 4))


if __name__ == "__main__":
    main()
