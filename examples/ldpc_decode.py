"""LDPC decoding over AWGN: BER-vs-SNR for max-product BP vs uncoded.

The paper motivates BP with error-correcting codes; this driver closes the
loop: a regular (n, dv, dc) Gallager code is encoded as a pairwise PGM
(``repro.pgm.ldpc_code`` -- check constraints become auxiliary vertices
with even-parity states), the channel is BPSK over AWGN, and decoding is
the *unchanged* engine with ``BPConfig(backend="maxprod")`` -- scheduling
is semiring-agnostic, so the whole scheduler/serving stack decodes codes
without modification.

For each SNR point the all-zero codeword is transmitted ``--words`` times
with fresh noise; the coded bit-error rate (max-product MAP + argmax
beliefs) is compared against the uncoded hard-decision BER on the same
received samples. The coded curve must drop below uncoded -- that gap is
the decoder doing real work, and ``benchmarks/bench_zoo.py`` pins it as an
acceptance number.

Run:  PYTHONPATH=src python examples/ldpc_decode.py [--words 8] \
          [--snr 1.0,2.0,3.0] [--n 48] [--scheduler rbp]
"""

import argparse
import time

import jax
import numpy as np

from repro.core import BPConfig, BPEngine, list_schedulers
from repro.core.messages import map_assignment
from repro.pgm import ldpc_code


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=48, help="code length (bits)")
    ap.add_argument("--dv", type=int, default=3, help="bit degree")
    ap.add_argument("--dc", type=int, default=6, help="check degree")
    ap.add_argument("--words", type=int, default=8,
                    help="codewords simulated per SNR point")
    ap.add_argument("--snr", type=str, default="1.0,2.0,3.0",
                    help="comma-separated SNR points (dB)")
    ap.add_argument("--scheduler", default="lbp", choices=list_schedulers())
    ap.add_argument("--max-rounds", type=int, default=400)
    args = ap.parse_args()

    engine = BPEngine(BPConfig(scheduler=args.scheduler, backend="maxprod",
                               eps=1e-4, max_rounds=args.max_rounds,
                               history=False))
    rate = 1.0 - args.dv / args.dc
    print(f"({args.n},{args.dv},{args.dc}) regular LDPC, rate {rate:.2f}, "
          f"{args.words} words/point, scheduler={args.scheduler}")
    print(f"{'snr_db':>7} {'uncoded_ber':>12} {'coded_ber':>10} "
          f"{'conv':>6} {'rounds':>7} {'wall_s':>7}")
    for snr_db in [float(s) for s in args.snr.split(",")]:
        t0 = time.perf_counter()
        coded = uncoded = bits = conv = 0
        rounds = []
        for w in range(args.words):
            inst = ldpc_code(args.n, dv=args.dv, dc=args.dc, snr_db=snr_db,
                             seed=1000 * w + 7)
            res = engine.run(inst.pgm, jax.random.key(w))
            decoded = np.asarray(map_assignment(inst.pgm, res.logm))
            coded += inst.coded_errors(decoded)
            uncoded += inst.uncoded_errors
            bits += inst.n_bits
            conv += int(bool(res.converged))
            rounds.append(int(res.rounds))
        print(f"{snr_db:7.1f} {uncoded / bits:12.4f} {coded / bits:10.4f} "
              f"{conv:3d}/{args.words:<2d} {np.mean(rounds):7.1f} "
              f"{time.perf_counter() - t0:7.2f}")


if __name__ == "__main__":
    main()
