"""Stereo-vision MRF: disparity decoding on a synthetic scene.

The paper motivates BP with vision workloads; this driver decodes a
truncated-linear stereo MRF (``repro.pgm.stereo_mrf``: a slanted disparity
plane with a raised foreground rectangle, noisy observations, the classic
grid energy) with max-product BP through the unchanged engine
(``BPConfig(backend="maxprod")``) and scores the labeling two ways:

- **accuracy**: fraction of pixels within +-1 disparity of ground truth
  (the complement of the standard bad-pixel metric) -- must beat the raw
  rounded observation, i.e. the smoothness term must actually denoise;
- **energy**: the MAP objective. BP's labeling should reach at-or-below
  the *ground truth's* energy (noise makes truth slightly suboptimal
  under its own posterior -- matching it is the decoding win).

Run:  PYTHONPATH=src python examples/stereo_bp.py [--height 12] \
          [--width 16] [--disp 8] [--scheduler rbp]
"""

import argparse
import time

import jax
import numpy as np

from repro.core import BPConfig, BPEngine, list_schedulers
from repro.core.messages import map_assignment
from repro.pgm import stereo_mrf


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--height", type=int, default=12)
    ap.add_argument("--width", type=int, default=16)
    ap.add_argument("--disp", type=int, default=8,
                    help="disparity levels (states per pixel)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scheduler", default="rbp", choices=list_schedulers())
    ap.add_argument("--max-rounds", type=int, default=2000)
    args = ap.parse_args()

    inst = stereo_mrf(args.height, args.width, args.disp, seed=args.seed)
    engine = BPEngine(BPConfig(scheduler=args.scheduler, backend="maxprod",
                               eps=1e-4, max_rounds=args.max_rounds,
                               history=False))
    t0 = time.perf_counter()
    res = engine.run(inst.pgm, jax.random.key(args.seed))
    n_pix = args.height * args.width
    labels = np.asarray(map_assignment(inst.pgm, res.logm))[:n_pix]
    wall = time.perf_counter() - t0

    obs_labels = np.clip(np.round(inst.obs), 0, args.disp - 1).astype(int)
    print(f"stereo {args.height}x{args.width}x{args.disp} "
          f"scheduler={args.scheduler}: converged={bool(res.converged)} "
          f"rounds={int(res.rounds)} wall={wall:.2f}s")
    print(f"accuracy(+-1): observation={inst.accuracy(obs_labels):.3f} "
          f"BP={inst.accuracy(labels):.3f}")
    print(f"energy: truth={inst.energy(inst.truth):.2f} "
          f"observation={inst.energy(obs_labels):.2f} "
          f"BP={inst.energy(labels):.2f} (lower is better)")
    disp_map = labels.reshape(args.height, args.width)
    print("decoded disparity map (rows top to bottom):")
    for row in disp_map:
        print("  " + "".join(f"{d:x}" for d in row))


if __name__ == "__main__":
    main()
