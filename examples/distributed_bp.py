"""Multi-device BP via shard_map (run with forced host devices on CPU).

Demonstrates the pod-scale path: edges sharded over a 1-D mesh, per-shard
threefry streams for the randomized filter, psum'd convergence votes.

Run:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/distributed_bp.py
"""

import os

if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp

from repro.core import BPConfig, BPEngine, LBP, RnBP
from repro.dist import make_bp_mesh, run_bp_sharded
from repro.pgm import ising_grid


def main():
    print(f"devices: {len(jax.devices())}")
    mesh = make_bp_mesh()
    pgm = ising_grid(48, 2.5, seed=0)
    print(f"Ising 48x48: {pgm.n_real_edges} directed edges over "
          f"{mesh.devices.size} shards")

    engine = BPEngine(BPConfig(scheduler="rnbp",
                               scheduler_kwargs={"low_p": 0.7},
                               eps=1e-3, max_rounds=6000))
    ref = engine.run(pgm, jax.random.key(0))
    print(f"single-device RnBP: rounds={int(ref.rounds)} "
          f"converged={bool(ref.converged)}")

    for sched in [LBP(), RnBP(low_p=0.7)]:
        t0 = time.perf_counter()
        res = run_bp_sharded(pgm, sched, mesh, jax.random.key(0),
                             eps=1e-3, max_rounds=6000)
        jax.block_until_ready(res.beliefs)
        diff = float(jnp.max(jnp.abs(jnp.where(
            pgm.state_mask, res.beliefs - ref.beliefs, 0.0))))
        print(f"sharded {type(sched).__name__:5s}: "
              f"rounds={int(res.rounds):5d} "
              f"converged={bool(res.converged)} "
              f"max-belief-diff-vs-ref={diff:.2e} "
              f"wall={time.perf_counter() - t0:.2f}s")


if __name__ == "__main__":
    main()
