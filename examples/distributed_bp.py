"""Multi-device BP via shard_map (run with forced host devices on CPU).

Demonstrates both pod-scale paths in ``repro.dist``:

- **sharded**: edges split over a 1-D mesh, per-vertex sums combined with
  one exact psum per round; works for any graph and any scheduler.
- **banded**: contiguous edge bands + neighbor-only halo exchange; only for
  banded graphs (grids/chains) but round-exact vs the single-device loop.

Run:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/distributed_bp.py [--size N]
"""

import argparse
import os

if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp

from repro.core import BPConfig, BPEngine, LBP, RnBP
from repro.dist import make_bp_mesh, run_bp_sharded
from repro.dist.bp_banded import partition_banded, run_bp_banded
from repro.pgm import ising_grid_fast


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=32,
                    help="Ising grid side (default 32; paper-ish scale 48+)")
    args = ap.parse_args()

    print(f"devices: {len(jax.devices())}")
    mesh = make_bp_mesh()
    pgm = ising_grid_fast(args.size, 2.5, seed=0)
    print(f"Ising {args.size}x{args.size}: {pgm.n_real_edges} directed "
          f"edges over {mesh.devices.size} shards")

    engine = BPEngine(BPConfig(scheduler="rnbp",
                               scheduler_kwargs={"low_p": 0.7},
                               eps=1e-3, max_rounds=6000))
    ref = engine.run(pgm, jax.random.key(0))
    print(f"single-device RnBP: rounds={int(ref.rounds)} "
          f"converged={bool(ref.converged)}")

    for sched in [LBP(), RnBP(low_p=0.7)]:
        t0 = time.perf_counter()
        res = run_bp_sharded(pgm, sched, mesh, jax.random.key(0),
                             eps=1e-3, max_rounds=6000)
        jax.block_until_ready(res.beliefs)
        diff = float(jnp.max(jnp.abs(jnp.where(
            pgm.state_mask, res.beliefs - ref.beliefs, 0.0))))
        print(f"sharded {type(sched).__name__:5s}: "
              f"rounds={int(res.rounds):5d} "
              f"converged={bool(res.converged)} "
              f"max-belief-diff-vs-ref={diff:.2e} "
              f"wall={time.perf_counter() - t0:.2f}s")

    # Banded halo-exchange path: round-exact LBP on the same grid.
    lbp_ref = BPEngine(BPConfig(scheduler="lbp", eps=1e-3,
                                max_rounds=6000)).run(pgm, jax.random.key(0))
    part = partition_banded(pgm, mesh.devices.size)
    t0 = time.perf_counter()
    _, rounds, done = run_bp_banded(part, LBP(), mesh, jax.random.key(0),
                                    eps=1e-3, max_rounds=6000)
    print(f"banded  LBP  : rounds={int(rounds):5d} converged={bool(done)} "
          f"round-parity-vs-ref={int(rounds) == int(lbp_ref.rounds)} "
          f"wall={time.perf_counter() - t0:.2f}s")


if __name__ == "__main__":
    main()
