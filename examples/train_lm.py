"""Train a small LM end-to-end through the production substrate.

Uses the same config/model/optimizer/data/checkpoint stack as the 512-chip
dry-run, scaled to CPU: a reduced qwen3-family model, a few hundred steps,
loss visibly decreasing, checkpoint + exact resume.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 60]
(Any assigned arch works: --arch granite_moe_3b_a800m trains the MoE.)
"""

import argparse
import dataclasses
import tempfile
import time

import jax

from repro.checkpoint import latest_step, restore_pytree, save_pytree
from repro.configs import get
from repro.configs.base import TRAIN_4K
from repro.data import SyntheticLM
from repro.models import build_model
from repro.train.step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get(args.arch).reduced()
    model = build_model(cfg)
    n_params = sum(x.size for x in jax.tree.leaves(
        model.init_params(jax.random.key(0))))
    print(f"{cfg.name}: {n_params / 1e6:.2f}M params (reduced config)")

    shape = dataclasses.replace(TRAIN_4K, seq_len=args.seq,
                                global_batch=args.batch)
    pipe = SyntheticLM(cfg, shape)
    step = jax.jit(make_train_step(model, base_lr=2e-3, warmup=10,
                                   total_steps=args.steps))
    state = init_train_state(model, jax.random.key(0))

    with tempfile.TemporaryDirectory() as ckpt:
        t0 = time.perf_counter()
        for i in range(args.steps):
            state, m = step(state, pipe.batch(i))
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss={float(m['loss']):.4f} "
                      f"lr={float(m['lr']):.2e}", flush=True)
            if i == args.steps // 2:
                save_pytree(ckpt, i + 1, state, extra={"data_step": i + 1})
        print(f"trained {args.steps} steps in "
              f"{time.perf_counter() - t0:.1f}s")

        # crash-resume drill: restore mid-run checkpoint, replay, compare
        s = latest_step(ckpt)
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            state)
        restored, extra = restore_pytree(ckpt, s, like)
        for i in range(extra["data_step"], args.steps):
            restored, m2 = step(restored, pipe.batch(i))
        drift = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(abs(a.astype("float32")
                                   - b.astype("float32")).max()),
            state.params, restored.params)))
        print(f"checkpoint-resume replay drift: {drift:.2e} (exact = 0)")


if __name__ == "__main__":
    main()
