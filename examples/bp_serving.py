"""End-to-end driver: batched BP inference service (the paper's workload).

The paper's algorithm is an *inference* engine, so the end-to-end driver is
a serving loop: a stream of PGM inference requests (mixed Ising / chain /
protein-like graphs) runs through the serving pipeline
(``repro.core.serving``) -- requests are grouped into shape-homogeneous
buckets, each bucket runs as one compiled program, and between chunks the
engine *evacuates* converged graphs (their results are released
immediately) and backfills the freed slots from the pending queue, so one
straggler no longer holds a whole bucket's worth of finished work hostage.

Default mode reproduces the legacy synchronous ``BPEngine.serve`` cadence
(one resident bucket, stream staged up front). ``--async`` switches to the
full pipeline: the request stream is consumed as an *online iterator*,
host-side padding/`device_put` staging overlaps device chunks across
double-buffered bucket slots, and once the queue drains the survivors are
*compacted* into narrower buckets so dead slots stop costing sweeps.

Knobs:
  --async          online iterator + double-buffered slots + compaction
  --growth         bucketing policy: 2.0 bounds padding waste for steady
                   traffic over few shape families, ``inf`` collapses a
                   shape-diverse cold stream into a single compilation
                   (sync mode only; online needs per-request shapes)
  --max-batch      resident bucket width (slots that evacuation recycles)
  --chunk-rounds   rounds per device chunk between evacuation sweeps
  --no-evacuate    PR-1 baseline: run every bucket to completion
  --policy         admission policy: fifo (default) | residual (co-batch
                   by expected effort) | windowed (delay for fullness) |
                   deadline (SLA tier: slack-ordered admission, slot
                   packing, mid-flight eviction of hopeless requests)
  --window-ms      windowed policy's admission window
  --slo-ms         per-request latency budget attached to the stream
                   (enables SLO-attainment reporting; the deadline
                   policy evicts what will miss it)
  --ingest-threads feeder threads pulling the stream behind a bounded
                   queue (0 = pull on the serving thread)
  --replicas       N > 1 serves through the router tier (repro.serve):
                   N pipelines on their own threads behind one front-end
  --routing        routing policy for the router tier: round_robin |
                   least_loaded | kind_affinity (docs/router.md)
  --steal          cross-replica work stealing: a drained replica pulls
                   a batch from the deepest peer's inbox
  --workload       request mix: legacy (historic 3-kind stream), mixed
                   (the full heterogeneous zoo_stream -- ising/chain/
                   protein/ldpc/stereo at mixed sizes), or any one
                   registered zoo generator (repro.pgm.WORKLOADS)
  --scheduler      message scheduler (rnbp default); --backend picks the
                   update backend -- these flags (and --policy/--routing)
                   take their choices from the live registries via
                   list_schedulers / list_backends /
                   list_admission_policies / list_routing_policies, so
                   --help always shows exactly what is registered

Run:  PYTHONPATH=src python examples/bp_serving.py [--async] [--requests 12]
      PYTHONPATH=src python examples/bp_serving.py --async \
          --policy residual --ingest-threads 2
      PYTHONPATH=src python examples/bp_serving.py \
          --replicas 2 --routing least_loaded --steal
"""

import argparse
import time

import jax
import numpy as np

from repro.core import (BPConfig, BPEngine, list_admission_policies,
                        list_backends, list_schedulers, serve_async)
from repro.pgm import (chain_graph, get_workload, ising_grid, list_workloads,
                       protein_like_graph, zoo_stream)
from repro.serve import list_routing_policies, serve_routed


def request_stream(n, workload="legacy"):
    """(rid, kind, pgm) triples: the historic 3-kind mix (``legacy``), the
    full heterogeneous zoo (``mixed``), or one registered zoo workload."""
    if workload == "legacy":
        kinds = [
            lambda s: ("ising30/C2.5", ising_grid(30, 2.5, seed=s)),
            lambda s: ("chain2000/C10", chain_graph(2000, seed=s)),
            lambda s: ("protein60", protein_like_graph(60, seed=s)),
        ]
        for i in range(n):
            yield (i,) + kinds[i % 3](i)
    elif workload == "mixed":
        for i, (kind, pgm) in enumerate(zoo_stream(n)):
            yield i, kind, pgm
    else:
        gen = get_workload(workload)
        for i in range(n):
            yield i, workload, gen(seed=i)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=9)
    ap.add_argument("--eps", type=float, default=1e-3)
    ap.add_argument("--async", dest="async_mode", action="store_true",
                    help="online pipeline: double-buffered slots, prefetch "
                         "staging, bucket compaction")
    ap.add_argument("--growth", type=float, default=2.0,
                    help="bucket edge-ceiling growth factor; inf = 1 bucket "
                         "(sync mode only: online bucketing needs "
                         "per-request shapes)")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="resident bucket width (evacuated slots backfill)")
    ap.add_argument("--chunk-rounds", type=int, default=512,
                    help="rounds per chunk between evacuation sweeps")
    ap.add_argument("--no-evacuate", action="store_true",
                    help="baseline: run each bucket to completion")
    # choices= come from the registries (repro.core.registry), so the CLI
    # surface cannot drift from what is actually registered.
    ap.add_argument("--policy", default="fifo",
                    choices=list_admission_policies(),
                    help="admission policy (docs/admission.md)")
    ap.add_argument("--scheduler", default="rnbp",
                    choices=list_schedulers(),
                    help="message scheduler (docs/schedulers.md); rnbp "
                         "(default) uses the paper's protein-run kwargs")
    ap.add_argument("--backend", default="ref", choices=list_backends(),
                    help="message-update backend (BPConfig.backend)")
    ap.add_argument("--window-ms", type=float, default=10.0,
                    help="windowed policy: admission window in ms")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="latency budget per request in ms; attaches "
                         "(rid, pgm, slo) triples to the stream and "
                         "reports SLO attainment + evictions")
    ap.add_argument("--ingest-threads", type=int, default=0,
                    help="feeder threads pulling the request stream "
                         "(0 = pull on the serving thread)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serving replicas behind the router tier "
                         "(repro.serve); > 1 implies the async pipeline")
    ap.add_argument("--routing", default="round_robin",
                    choices=list_routing_policies(),
                    help="router placement policy (docs/router.md)")
    ap.add_argument("--steal", action="store_true",
                    help="cross-replica work stealing when a replica's "
                         "pending work drains below its low watermark")
    ap.add_argument("--workload", default="legacy",
                    choices=["legacy", "mixed"] + list_workloads(),
                    help="request mix: the historic 3-kind stream "
                         "(legacy), the heterogeneous zoo_stream (mixed), "
                         "or one registered zoo generator "
                         "(docs/workloads.md)")
    args = ap.parse_args()

    sched_kwargs = ({"low_p": 0.4, "high_p": 0.9}  # paper's protein run
                    if args.scheduler == "rnbp" else {})
    engine = BPEngine(BPConfig(
        scheduler=args.scheduler, scheduler_kwargs=sched_kwargs,
        backend=args.backend,
        eps=args.eps, max_rounds=6000, history=False))

    t_all = time.perf_counter()
    kinds = {}
    admission_kwargs = ({"window_s": args.window_ms / 1e3}
                        if args.policy == "windowed" else {})
    kw = dict(max_batch=args.max_batch, chunk_rounds=args.chunk_rounds,
              evacuate=not args.no_evacuate, admission=args.policy,
              admission_kwargs=admission_kwargs,
              ingest_threads=args.ingest_threads)

    slo_s = None if args.slo_ms is None else args.slo_ms / 1e3

    def online():
        # Online path: the generator is consumed lazily; each request is
        # padded + device_put the moment it is pulled (bucket_shape
        # ceilings), overlapped with the in-flight device chunks. With an
        # SLO the items become (rid, pgm, slo) deadline triples.
        for rid, kind, pgm in request_stream(args.requests, args.workload):
            kinds[rid] = kind
            yield pgm if slo_s is None else (rid, pgm, slo_s)

    if args.replicas > 1:
        print(f"{args.requests} requests (router tier: {args.replicas} "
              f"replicas, routing={args.routing}, steal={args.steal}, "
              f"policy={args.policy})", flush=True)
        rep = serve_routed(engine, online(), jax.random.key(0),
                           replicas=args.replicas, routing=args.routing,
                           steal=args.steal, growth=args.growth, slots=2,
                           prefetch=2 * args.max_batch, **kw)
    elif args.async_mode:
        print(f"{args.requests} requests (async pipeline, "
              f"width={args.max_batch}, policy={args.policy}, "
              f"ingest_threads={args.ingest_threads})", flush=True)
        rep = serve_async(engine, online(), jax.random.key(0),
                          growth=args.growth, slots=2,
                          prefetch=2 * args.max_batch, **kw)
    else:
        stream = list(request_stream(args.requests, args.workload))
        kinds = {r[0]: r[1] for r in stream}
        pgms = [r[2] for r in stream]
        t_build = time.perf_counter() - t_all
        print(f"{args.requests} requests (growth={args.growth}, "
              f"width={args.max_batch}); build {t_build:.2f}s", flush=True)
        # Same bitwise results as engine.serve(...) -- the materialized
        # plan with one resident slot is the legacy driver -- but routed
        # through the pipeline so per-request latency is recorded. (With
        # an SLO the stream carries deadline triples and runs online.)
        items = pgms if slo_s is None else [
            (r[0], r[2], slo_s) for r in stream]
        rep = serve_async(engine, items, jax.random.key(0),
                          growth=args.growth, compact=False, slots=1,
                          prefetch=None, **kw)

    done = failed = 0
    by_rid = {rec.rid: rec for rec in rep.records}
    for rid in sorted(by_rid):
        rec = by_rid[rid]
        ok = bool(rec.result.converged)
        done += ok
        failed += not ok
        tag = "EVIC" if rec.evicted else ("ok  " if ok else "FAIL")
        marg = np.exp(np.asarray(rec.result.beliefs[0]))
        where = (f" r{rec.replica}{'*' if rec.stolen else ' '}"
                 if args.replicas > 1 else "")
        print(f"req {rid:3d} {kinds[rid]:14s} "
              f"{tag} rounds={int(rec.result.rounds):5d} "
              f"latency={rec.latency_s * 1e3:8.1f}ms "
              f"(queue {rec.queue_s * 1e3:7.1f}ms){where} "
              f"P(x0)={np.round(marg[:2], 3)}", flush=True)

    s = rep.stats
    wall = time.perf_counter() - t_all
    pct = rep.latency_percentiles((50, 95, 99))
    # Admission wait and device residency report separately: the wait is
    # what the admission policy trades (windowed raises it for fuller
    # buckets), the service time is what the device actually cost.
    adm = rep.latency_percentiles((50, 95, 99), field="admission")
    svc = rep.latency_percentiles((50, 95, 99), field="service")
    policy = (f"routing={s.policy}" if args.replicas > 1
              else f"policy={s.policy}")
    print(f"\nserved {done}/{args.requests} converged "
          f"({failed} unconverged) in {wall:.1f}s "
          f"({args.requests / wall:.1f} graphs/s, {policy})")
    if slo_s is not None:
        attained = sum(1 for rec in rep.records if rec.within_slo)
        evicted = sum(1 for rec in rep.records if rec.evicted)
        print(f"SLO {args.slo_ms:.0f}ms: attainment "
              f"{attained}/{len(rep.records)} "
              f"({100 * attained / max(len(rep.records), 1):.0f}%), "
              f"{evicted} evicted")
    print(f"latency ms:        p50={pct['p50']:.1f} p95={pct['p95']:.1f} "
          f"p99={pct['p99']:.1f}")
    print(f"admission-wait ms: p50={adm['p50']:.1f} p95={adm['p95']:.1f} "
          f"p99={adm['p99']:.1f}")
    print(f"service ms:        p50={svc['p50']:.1f} p95={svc['p95']:.1f} "
          f"p99={svc['p99']:.1f}")
    if args.replicas > 1:
        # * in the request lines marks work-stolen requests.
        print(f"replicas={s.replicas} routed={s.routed} "
              f"steals={s.steals} stolen={s.stolen} "
              f"sweeps: device={rep.device_sweeps} "
              f"useful={rep.useful_sweeps} wasted={rep.wasted_sweeps}")
    else:
        print(f"chunks={s.chunks} evacuated={s.evacuated} "
              f"backfilled={s.backfilled} compactions={s.compactions} "
              f"admission_holds={s.admission_holds} "
              f"sweeps: device={s.device_sweeps} "
              f"useful={s.useful_sweeps} wasted={s.wasted_sweeps}")


if __name__ == "__main__":
    main()
