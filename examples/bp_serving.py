"""End-to-end driver: batched BP inference service (the paper's workload).

The paper's algorithm is an *inference* engine, so the end-to-end driver is
a serving loop: a stream of PGM inference requests (mixed Ising / chain /
protein-like graphs) is micro-batched by the bucketed engine
(``repro.core.batch``) -- requests are grouped into shape-homogeneous
buckets and each bucket runs as ONE ``run_bp_batch`` call (one compilation,
one device program per bucket shape instead of one per request shape).
The ``--growth`` knob picks the bucketing policy: 2.0 bounds padding waste
for steady traffic over few shape families, ``inf`` collapses a shape-
diverse cold stream into a single compilation.

Run:  PYTHONPATH=src python examples/bp_serving.py [--requests 12]
"""

import argparse
import time

import jax
import numpy as np

from repro.core import RnBP, bucket_pgms, run_bp_batch
from repro.ft import StragglerMonitor
from repro.pgm import chain_graph, ising_grid, protein_like_graph


def request_stream(n):
    kinds = [
        lambda s: ("ising30/C2.5", ising_grid(30, 2.5, seed=s)),
        lambda s: ("chain2000/C10", chain_graph(2000, seed=s)),
        lambda s: ("protein60", protein_like_graph(60, seed=s)),
    ]
    for i in range(n):
        yield (i,) + kinds[i % 3](i)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=9)
    ap.add_argument("--eps", type=float, default=1e-3)
    ap.add_argument("--growth", type=float, default=2.0,
                    help="bucket edge-ceiling growth factor; inf = 1 bucket")
    args = ap.parse_args()

    sched = RnBP(low_p=0.4, high_p=0.9)   # paper's protein settings
    monitor = StragglerMonitor()
    rng = jax.random.key(0)

    t_all = time.perf_counter()
    stream = list(request_stream(args.requests))
    req_ids = [r[0] for r in stream]
    kinds = {r[0]: r[1] for r in stream}
    pgms = [r[2] for r in stream]
    t_build = time.perf_counter() - t_all

    buckets = bucket_pgms(pgms, growth=args.growth)
    print(f"{args.requests} requests -> {len(buckets)} buckets "
          f"(growth={args.growth}); build {t_build:.2f}s", flush=True)

    done = failed = 0
    rows = {}
    for b, bucket in enumerate(buckets):
        t0 = time.perf_counter()
        # key by *input* position (as run_bp_many does) so results are
        # independent of the bucketing policy
        keys = jax.numpy.stack([jax.random.fold_in(rng, gi)
                                for gi in bucket.indices])
        res = run_bp_batch(bucket.batch, sched, keys, eps=args.eps,
                           max_rounds=6000)
        jax.block_until_ready(res.logm)
        dt = time.perf_counter() - t0
        straggler = monitor.record(dt)
        print(f"bucket {b}: {len(bucket.indices)} graphs "
              f"E={bucket.batch.n_edges} S={bucket.batch.n_states_max} "
              f"wall={dt:5.2f}s"
              + ("  [straggler]" if straggler else ""), flush=True)
        beliefs = np.asarray(res.beliefs)
        for j, gi in enumerate(bucket.indices):
            ok = bool(res.converged[j])
            done += ok
            failed += not ok
            marg = np.exp(beliefs[j, 0])
            rows[req_ids[gi]] = (
                f"req {req_ids[gi]:3d} {kinds[req_ids[gi]]:14s} "
                f"{'ok  ' if ok else 'FAIL'} rounds={int(res.rounds[j]):5d} "
                f"P(x0)={np.round(marg[:2], 3)}")
    for rid in req_ids:
        print(rows[rid], flush=True)
    wall = time.perf_counter() - t_all
    print(f"\nserved {done}/{args.requests} converged "
          f"({failed} unconverged) in {wall:.1f}s "
          f"({args.requests / wall:.1f} graphs/s); "
          f"straggler events: {monitor.events}")


if __name__ == "__main__":
    main()
