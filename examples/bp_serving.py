"""End-to-end driver: batched BP inference service (the paper's workload).

The paper's algorithm is an *inference* engine, so the end-to-end driver is
a serving loop: a stream of PGM inference requests (mixed Ising / chain /
protein-like graphs) processed by RnBP with checkpointed, straggler-
monitored, chunked execution -- the production path a cluster deployment
would run per-request-shard.

Run:  PYTHONPATH=src python examples/bp_serving.py [--requests 12]
"""

import argparse
import time

import jax
import numpy as np

from repro.core import RnBP, run_bp
from repro.ft import StragglerMonitor
from repro.pgm import chain_graph, ising_grid, protein_like_graph


def request_stream(n):
    kinds = [
        lambda s: ("ising30/C2.5", ising_grid(30, 2.5, seed=s)),
        lambda s: ("chain2000/C10", chain_graph(2000, seed=s)),
        lambda s: ("protein60", protein_like_graph(60, seed=s)),
    ]
    for i in range(n):
        yield (i,) + kinds[i % 3](i)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=9)
    ap.add_argument("--eps", type=float, default=1e-3)
    args = ap.parse_args()

    sched = RnBP(low_p=0.4, high_p=0.9)   # paper's protein settings
    monitor = StragglerMonitor()
    done = failed = 0
    t_all = time.perf_counter()
    for req_id, kind, pgm in request_stream(args.requests):
        t0 = time.perf_counter()
        res = run_bp(pgm, sched, jax.random.fold_in(jax.random.key(0),
                                                    req_id),
                     eps=args.eps, max_rounds=6000)
        jax.block_until_ready(res.logm)
        dt = time.perf_counter() - t0
        straggler = monitor.record(dt)
        ok = bool(res.converged)
        done += ok
        failed += not ok
        marg = np.exp(np.asarray(res.beliefs))[0]
        print(f"req {req_id:3d} {kind:14s} "
              f"{'ok  ' if ok else 'FAIL'} rounds={int(res.rounds):5d} "
              f"wall={dt:5.2f}s P(x0)={np.round(marg[:2], 3)}"
              + ("  [straggler]" if straggler else ""), flush=True)
    print(f"\nserved {done}/{args.requests} converged "
          f"({failed} unconverged) in {time.perf_counter() - t_all:.1f}s; "
          f"straggler events: {monitor.events}")


if __name__ == "__main__":
    main()
