"""Render the EXPERIMENTS.md roofline table from experiments/dryrun/*.json."""

import glob
import json
import os
import sys

HW_NOTE = "197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link (TPU v5e)"


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def render(d="experiments/dryrun", mesh_filter="16x16"):
    rows = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(path))
        if r.get("status") != "ok" or r["mesh"] != mesh_filter:
            continue
        terms = {"compute": r["t_compute"], "memory": r["t_memory"],
                 "collective": r["t_collective"]}
        dom = r["bottleneck"]
        t_dom = terms[dom]
        t_comp = terms["compute"]
        frac = t_comp / max(sum(terms.values()), 1e-30)
        fit = (r.get("memory_per_device") or {}).get("peak_ok_16GB", None)
        rows.append({
            "cell": f"{r['arch']} x {r['shape']}",
            "kind": r["kind"],
            "t_c": terms["compute"], "t_m": terms["memory"],
            "t_x": terms["collective"], "dom": dom,
            "useful": r["useful_ratio"],
            "roofline_frac": frac, "fits": fit,
        })
    print(f"| cell | kind | compute | memory | collective | bottleneck | "
          f"useful (6ND/HLO) | roofline frac | fits 16GB |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['cell']} | {r['kind']} | {fmt_s(r['t_c'])} | "
              f"{fmt_s(r['t_m'])} | {fmt_s(r['t_x'])} | **{r['dom']}** | "
              f"{r['useful']:.2f} | {r['roofline_frac']:.2f} | "
              f"{'yes' if r['fits'] else 'NO' if r['fits'] is not None else '?'} |")


if __name__ == "__main__":
    render(mesh_filter=sys.argv[1] if len(sys.argv) > 1 else "16x16")
