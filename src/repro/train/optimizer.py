"""AdamW with decoupled weight decay, built from scratch (no optax).

Mixed precision: master weights/moments in f32 regardless of compute dtype;
grads arrive in compute dtype and are upcast. Moments are sharded like their
parameters (the pjit sharding rules apply pointwise over the state pytree).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AdamWState:
    mu: Any
    nu: Any
    count: jax.Array


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params),
                      count=jnp.zeros((), jnp.int32))


def adamw_update(params, grads, state: AdamWState, *,
                 lr: float | jax.Array = 3e-4, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_clip: float = 1.0):
    """Returns (new_params, new_state, grad_norm). Global-norm clipping."""
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(g32)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if grad_clip > 0 else 1.0
    count = state.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        step = (mu / c1) / (jnp.sqrt(nu / c2) + eps)
        # decay only matrices (norms/scalars exempt), the usual rule
        wd = weight_decay if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (step + wd * p.astype(jnp.float32))
        return newp.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(g32)
    flat_mu = jax.tree.leaves(state.mu)
    flat_nu = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(new_mu, new_nu, count), gnorm


def cosine_lr(step: jax.Array, *, base_lr: float, warmup: int,
              total: int, min_frac: float = 0.1) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(s < warmup, warm, cos)
