"""Train step factory: loss -> grad -> AdamW, with optional microbatch
gradient accumulation (scan over microbatches; XLA overlaps the per-micro
reduce-scatter of grads with the next micro's compute -- the standard
latency-hiding trick at pod scale)."""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.train.optimizer import (AdamWState, adamw_init, adamw_update,
                                   cosine_lr)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TrainState:
    params: Any
    opt: AdamWState
    step: jax.Array


def init_train_state(model: Model, key: jax.Array) -> TrainState:
    params = model.init_params(key)
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32))


def train_state_specs(model: Model) -> TrainState:
    return jax.eval_shape(lambda k: init_train_state(model, k),
                          jax.random.key(0))


def make_train_step(model: Model, *, base_lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10_000,
                    microbatches: int = 1, remat: bool = True,
                    grad_shardings=None):
    """Returns train_step(state, batch) -> (state, metrics).

    With microbatches > 1, the leading batch dim of every batch array is
    split into that many chunks and gradients are accumulated in f32.
    grad_shardings (optional): sharding tree pinned onto the gradients
    before the optimizer -- under FSDP this turns the gradient all-reduce
    into a reduce-scatter (each device only needs its parameter shard's
    gradient), halving gradient bytes on the wire.
    """

    def loss_fn(params, batch):
        # Cast matrices to the compute dtype ONCE at step entry: under FSDP
        # the partitioner then all-gathers the bf16 copy instead of the f32
        # master (halves param-AG bytes; the in-layer .astype becomes a
        # no-op). Norm vectors stay f32.
        cast = jax.tree.map(
            lambda p: p.astype(model.dtype)
            if (p.ndim >= 2 and p.dtype == jnp.float32) else p, params)
        loss, metrics = model.forward_train(cast, batch, remat=remat)
        return loss, metrics

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if microbatches == 1:
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape((microbatches, b // microbatches)
                                 + x.shape[1:])
            micro = jax.tree.map(split, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

            def acc(carry, mb):
                g_acc, m_acc = carry
                (_, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(state.params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                m_acc = jax.tree.map(lambda a, b: a + b, m_acc, metrics)
                return (g_acc, m_acc), None

            metrics0 = jax.eval_shape(
                lambda p, b: loss_fn(p, b)[1], state.params,
                jax.tree.map(lambda x: x[0], micro))
            metrics0 = jax.tree.map(
                lambda sd: jnp.zeros(sd.shape, sd.dtype), metrics0)
            (grads, metrics), _ = jax.lax.scan(
                acc, (zeros, metrics0), micro)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m / microbatches, metrics)

        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        lr = cosine_lr(state.step, base_lr=base_lr, warmup=warmup,
                       total=total_steps)
        params, opt, gnorm = adamw_update(state.params, grads, state.opt,
                                          lr=lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return TrainState(params, opt, state.step + 1), metrics

    return train_step
