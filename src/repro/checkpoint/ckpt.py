"""Mesh-agnostic checkpointing with atomic commit.

Format: one directory per step --
    <dir>/step_000123.tmp/  (written)  -> atomic rename -> <dir>/step_000123/
        manifest.json   {step, keys, shapes, dtypes, extra}
        data.npz        flattened leaves keyed by pytree path

Leaves are gathered to host (fully replicated numpy) before saving, so a
checkpoint written on a 512-chip mesh restores on any other mesh -- elastic
restarts re-shard at load via device_put against the new sharding. For
multi-TB states this would switch to per-shard tensorstore writes; the
format keeps that swap behind save/restore.

Fault-tolerance contract: a crash mid-save leaves only a ``.tmp`` dir which
``latest_step`` ignores; the previous checkpoint stays valid.
"""

from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save_pytree(directory: str, step: int, tree: Any,
                extra: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:09d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "data.npz"), **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic commit
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore_pytree(directory: str, step: int, like: Any,
                   sharding_tree: Any = None) -> Tuple[Any, dict]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). If ``sharding_tree`` is given, leaves are device_put
    against it (re-sharding for the current mesh)."""
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "data.npz"))
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)
    out_leaves = []
    shard_leaves = (jax.tree.leaves(sharding_tree)
                    if sharding_tree is not None else None)
    for i, (kp, leaf) in enumerate(leaves_paths[0]):
        key = "/".join(str(p) for p in kp)
        arr = data[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                       leaf.shape)
        if shard_leaves is not None:
            arr = jax.device_put(arr, shard_leaves[i])
        out_leaves.append(arr)
    return jax.tree.unflatten(leaves_paths[1], out_leaves), manifest["extra"]
