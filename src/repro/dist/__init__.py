"""Multi-device BP: shard the folded edge axis over a JAX mesh.

The paper saturates one device by exposing more parallelism per BP round;
this subsystem takes the next axis -- *multiple* devices -- by sharding the
directed-edge dimension (the ``(E,)`` axis of ``logm``/residuals, or the
folded ``(B*E)`` axis of a bucket) over a 1-D mesh:

- every shard owns a contiguous, equal slice of the edge axis and runs the
  unmodified per-edge message math (``repro.core.messages``) on its slice,
- the one cross-edge coupling -- the per-vertex incoming-message sum -- is a
  local ``segment_sum`` into the (small, replicated) vertex axis followed by
  one ``psum``. Vertices whose incoming edges span shards get their partial
  sums combined in shard order rather than edge order, so results match
  single-device up to float reassociation (~1e-6 in beliefs; the banded
  path below is the bitwise-exact alternative for graphs that support it),
- reverse-message lookups (``logm[edge_rev]``) stay shard-local because the
  builders emit directed pairs at adjacent even-aligned indices ``(2k,
  2k+1)`` and shard boundaries are kept even (see ``make_sharded_update``).

The sharded update is an ordinary ``(pgm, logm) -> (cand, resid)`` backend
registered as ``"sharded"`` in ``repro.kernels.ops.UPDATE_BACKENDS``, so the
whole engine stack -- chunked ``BPEngine.step`` resume, evacuating ``serve``,
the batched disjoint-union fold -- runs unmodified on a mesh:

    engine = BPEngine(BPConfig(scheduler="rnbp", backend="sharded"))

Relaxed/partitioned schedulers keep converging under exactly this kind of
distribution (Aksenov et al., 2020); ``repro.dist.bp_banded`` adds the
stricter halo-exchange path for banded graphs where neighbor-only
communication suffices and LBP trajectories are reproduced round-exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import messages as M
from repro.core.engine import BPConfig, BPEngine, BPResult, BPState
from repro.core.graph import NEG_INF, PGM, pad_pgm
from repro.core.schedulers.base import Scheduler

from repro.dist.bp_banded import (BandedPartition, partition_banded,
                                  run_bp_banded)

#: Default mesh axis name for the sharded edge dimension.
BP_AXIS = "bp"


def make_bp_mesh(n_devices: int | None = None, *,
                 axis: str = BP_AXIS) -> Mesh:
    """1-D device mesh over the BP edge axis.

    Returns a ``jax.sharding.Mesh`` of shape ``(n_devices,)`` with one axis
    named ``axis`` (default ``"bp"``), using the first ``n_devices`` of
    ``jax.devices()`` (all of them when ``None``). Works with any device
    count, including ``--xla_force_host_platform_device_count`` CPU meshes.
    """
    n = n_devices or len(jax.devices())
    return jax.make_mesh((n,), (axis,))


def _check_edge_layout(pgm: PGM, n_shards: int) -> None:
    """Host-side validation of the sharding contract on a concrete PGM:
    equal even-sized shards, and every reverse edge co-resident with its
    partner (true by construction for all builders in ``repro.core.graph``
    and for ``BatchedPGM.folded()``)."""
    e = pgm.n_edges
    if e % n_shards:
        raise ValueError(
            f"padded edge count {e} not divisible by {n_shards} shards")
    size = e // n_shards
    if size % 2:
        raise ValueError(
            f"shard size {size} is odd: directed pairs (2k, 2k+1) would "
            "split across shards")
    rev = np.asarray(pgm.edge_rev)
    shard_of = np.arange(e) // size
    if not np.all(shard_of == shard_of[rev]):
        raise ValueError(
            "edge_rev crosses a shard boundary; re-pad with "
            "build_pgm/pad_pgm")


def shard_pgm(pgm: PGM, mesh: Mesh, *, axis: str = BP_AXIS) -> PGM:
    """Place a PGM's arrays on ``mesh``: edge-axis leaves sharded over
    ``axis``, vertex-axis leaves (``log_psi_v``/``state_mask``/``n_states``,
    all small) replicated. Shapes/dtypes are unchanged; only device layout
    moves. The padded edge count must divide the mesh size into even shards
    (see ``run_bp_sharded``, which re-pads automatically)."""
    _check_edge_layout(pgm, mesh.shape[axis])
    edge = NamedSharding(mesh, P(axis))
    edge3 = NamedSharding(mesh, P(axis, None, None))
    rep = NamedSharding(mesh, P())
    rep2 = NamedSharding(mesh, P(None, None))
    import dataclasses
    return dataclasses.replace(
        pgm,
        edge_src=jax.device_put(pgm.edge_src, edge),
        edge_dst=jax.device_put(pgm.edge_dst, edge),
        edge_rev=jax.device_put(pgm.edge_rev, edge),
        edge_mask=jax.device_put(pgm.edge_mask, edge),
        log_psi_e=jax.device_put(pgm.log_psi_e, edge3),
        log_psi_v=jax.device_put(pgm.log_psi_v, rep2),
        state_mask=jax.device_put(pgm.state_mask, rep2),
        n_states=jax.device_put(pgm.n_states, NamedSharding(mesh, P(None))),
        edge_count=(None if pgm.edge_count is None
                    else jax.device_put(pgm.edge_count, rep)),
        vertex_count=(None if pgm.vertex_count is None
                      else jax.device_put(pgm.vertex_count, rep)))


def make_sharded_update(mesh: Mesh | None = None, *, axis: str = BP_AXIS):
    """Build the mesh-sharded message-update backend.

    Returns an ``update_fn(pgm, logm) -> (cand (E, S) f32, resid (E,) f32)``
    with the exact signature/semantics of ``repro.core.messages.ref_update``
    (equal up to float reassociation in the per-vertex reduction for
    vertices whose incoming edges span shards), implemented as a
    ``shard_map`` over ``mesh``'s ``axis``: per-edge work is 1/n per
    device; the only collective is one ``psum`` of the (V, S) incoming-sum
    table per call. With ``mesh=None`` a mesh over all devices
    is built at factory time -- this is what the registry entry
    ``UPDATE_BACKENDS["sharded"]`` uses, so ``BPConfig(backend="sharded")``
    stays a plain serializable string (and the engine's batch fold can read
    ``update_fn.mesh`` before the first call).

    Contract on ``pgm``: the padded edge count must split into even-sized
    shards (``E % n == 0`` and ``E/n`` even) with reverse pairs
    co-resident. The builders' even-pair layout handles co-residency for
    any even split; divisibility is the caller's: ``run_bp_sharded``
    re-pads single graphs automatically, while the batched fold does not --
    a bucket's folded ``B*E`` axis (always a multiple of ``EDGE_PAD=128``)
    must divide the mesh, so keep mesh sizes at powers of two <= 64 or
    re-pad the bucket yourself.
    """
    if mesh is None:
        mesh = make_bp_mesh(axis=axis)
    m = mesh

    def update_fn(pgm: PGM, logm: jax.Array):
        n = m.shape[axis]
        e = logm.shape[0]
        v = pgm.log_psi_v.shape[0]
        if e % n or (e // n) % 2:
            raise ValueError(
                f"edge axis {e} does not split into even shards over "
                f"{n} devices; pad with pad_pgm (run_bp_sharded does this)")

        def body(src, dst, rev, emask, psi_e, psi_v, smask, logm_sh):
            # Local reverse lookup: pairs are co-resident by contract.
            off = jax.lax.axis_index(axis) * (e // n)
            contrib = jnp.where(emask[:, None], logm_sh, 0.0)
            part = jax.ops.segment_sum(contrib, dst, num_segments=v)
            vsum = jax.lax.psum(part, axis)           # exact: others add 0.0
            pre = psi_v[src] + vsum[src] - logm_sh[rev - off]
            pre = jnp.where(smask[src], pre, NEG_INF)
            cand = M.propagate_ref(psi_e, pre)
            return M.normalize_and_residual(cand, logm_sh, smask[dst], emask)

        es, es2 = P(axis), P(axis, None)
        return shard_map(
            body, mesh=m,
            in_specs=(es, es, es, es, P(axis, None, None),
                      P(None, None), P(None, None), es2),
            out_specs=(es2, es),
            check_rep=False)(
            pgm.edge_src, pgm.edge_dst, pgm.edge_rev, pgm.edge_mask,
            pgm.log_psi_e, pgm.log_psi_v, pgm.state_mask, logm)

    update_fn.mesh = m             # engine/batch fold reads this seam
    update_fn.axis = axis
    return update_fn


def make_sharded_engine(scheduler: Scheduler | str, mesh: Mesh | None = None,
                        *, axis: str = BP_AXIS, **config) -> BPEngine:
    """A ``BPEngine`` whose message update runs sharded over ``mesh``.

    ``scheduler`` is a ``Scheduler`` instance or registry spec string;
    ``config`` holds the remaining ``BPConfig`` fields (eps, max_rounds,
    damping, chunk_rounds, history, ...). Scheduler selection, convergence
    voting and frontier commits stay in the engine's jitted chunk and are
    partitioned by XLA around the shard_map'd update, so ``init``/``step``
    resume and ``serve`` evacuation work unchanged under sharding.
    """
    return BPEngine(BPConfig(scheduler=scheduler,
                             backend=make_sharded_update(mesh, axis=axis),
                             **config))


def run_bp_sharded(pgm: PGM, scheduler: Scheduler | str, mesh: Mesh,
                   rng: jax.Array, *, eps: float = 1e-3,
                   max_rounds: int = 2000, damping: float = 0.0,
                   chunk_rounds: int | None = None, history: bool = True,
                   axis: str = BP_AXIS) -> BPResult:
    """One-shot sharded BP: beliefs for ``pgm`` computed over ``mesh``.

    Shapes/dtypes match the single-device engine exactly: returns a
    ``BPResult`` with ``beliefs (V, S) f32`` log-marginals, ``logm (E', S)``
    final messages (``E'`` = edge count re-padded to split evenly over the
    mesh; real-edge prefix identical layout), int32 ``rounds``, bool
    ``converged``. Convergence semantics are the engine's: ``converged`` is
    True iff every real edge's residual fell below ``eps`` within
    ``max_rounds`` sweeps.

    Deterministic schedulers (LBP) follow the single-device trajectory up to
    float reassociation in the per-vertex reduction (beliefs typically agree
    to ~1e-6); stochastic schedulers (RnBP/RBP) draw the *same* per-edge
    randomness as single-device runs -- the RNG stream lives in the engine
    loop, outside the shard_map -- so trajectories match to the same
    tolerance. Graphs whose padded edge count does not divide the mesh are
    re-padded with inert edges (contents unchanged).
    """
    n = mesh.shape[axis]
    e = pgm.n_edges
    quantum = 2 * n
    need = ((e + quantum - 1) // quantum) * quantum
    if need != e:
        pgm = pad_pgm(pgm, n_edges=need, n_vertices=pgm.n_vertices,
                      n_states=pgm.n_states_max)
    engine = make_sharded_engine(scheduler, mesh, axis=axis, eps=eps,
                                 max_rounds=max_rounds, damping=damping,
                                 chunk_rounds=chunk_rounds, history=history)
    return engine.run(shard_pgm(pgm, mesh, axis=axis), rng)


__all__ = [
    "BP_AXIS", "make_bp_mesh", "shard_pgm", "make_sharded_update",
    "make_sharded_engine", "run_bp_sharded",
    "BandedPartition", "partition_banded", "run_bp_banded",
]
