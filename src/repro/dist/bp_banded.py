"""Banded BP: contiguous edge partitions + neighbor-only halo exchange.

``repro.dist`` (the general sharded path) pays one all-reduce of the (V, S)
vertex table per round. For *banded* graphs -- chains, grids, any MRF whose
adjacency matrix has small bandwidth under its natural vertex order -- that
collective is overkill: a contiguous vertex block only ever needs messages
from the blocks directly beside it. This module exploits that:

- ``partition_banded(pgm, n)`` reorders the real directed edges into global
  *stable destination order* and cuts them into ``n`` contiguous bands at
  vertex-block boundaries (blocks balanced by in-degree). The banded
  contract -- **every edge connects vertices in the same or adjacent
  blocks** -- is asserted; irregular graphs (random geometric / protein-like
  contact maps) are rejected with ``AssertionError``.
- ``run_bp_banded(part, sched, mesh, rng)`` runs the frontier loop with each
  band resident on one device. Per round each shard exchanges its message
  band with its two neighbors only (``lax.ppermute`` halo exchange, no
  all-reduce of message data), rebuilds the incoming-sum table for exactly
  the vertices its band touches, and commits its own band's frontier. The
  only global collective is the scalar unconverged-edge count (an exact
  integer psum shared by the convergence vote and RnBP's controller).

Round-exactness: a vertex's incoming edges all live in its own band, and the
stable sort preserves their original relative order, so the per-vertex sums
add the same values in the same order as the single-device reference --
banded LBP reproduces the reference trajectory (and therefore the round
count) exactly. Stochastic schedulers (RnBP) use *per-shard* RNG streams
(``fold_in(rng, shard)``); they converge to the same quality but not the
same trajectory.

Priority scheduling: *exact* sort-based schedulers (RBP/RS) need a global
top-k per round, which defeats neighbor-only communication -- they raise
the registry-style unsupported error below; use ``run_bp_sharded`` for
them. The *relaxed* priority family (RLX/RLXTree) is supported natively:
band slots are already in stable destination order, so contiguous
band-local queues are simultaneously storage-contiguous (rlx's partition)
and destination-ordered (rlxtree's structural partition) -- the two
coincide here, and per-queue top-k selection stays entirely shard-local
(per-shard RNG streams like RnBP, each shard force-including its own
max-residual queue), preserving the banded invariant that the only global
collective is the scalar unconverged count. ``BANDED_SCHEDULERS`` names
the supported subset; unsupported schedulers raise ``NotImplementedError``
with the uniform registry message format ("unknown banded scheduler ...;
registered: [...]").
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import messages as M
from repro.core.graph import NEG_INF, PGM
from repro.core.registry import Registry
from repro.core.schedulers import LBP, RLX, RLXTree, RnBP, get_scheduler
from repro.core.schedulers.rlx import queue_count, relaxed_frontier

#: The scheduler subset the banded runner supports (see module docstring:
#: exact sort-based priorities need a global top-k and are excluded).
#: Same Registry class as ``SCHEDULERS`` so the unsupported-scheduler
#: error carries the uniform "unknown X ...; registered: [...]" format.
BANDED_SCHEDULERS = Registry("banded scheduler", {
    "lbp": LBP,
    "rlx": RLX,
    "rlxtree": RLXTree,
    "rnbp": RnBP,
})


@dataclasses.dataclass(frozen=True)
class BandedPartition:
    """``n`` contiguous edge bands of a banded PGM, padded to equal length.

    Slot layout: band ``s`` occupies flattened slot coordinates
    ``[s*band_len, (s+1)*band_len)``; real slots are the band's edges in
    global stable-dst order, trailing slots are inert (mask False, pointing
    at the dummy vertex). All per-slot arrays are shaped ``(n, band_len)``
    (``log_psi_e``: ``(n, band_len, S, S)`` f32); ``edge_rev`` holds
    *flattened slot* coordinates of the reverse edge (always in the same or
    an adjacent band -- the banded contract). ``slot_edge`` maps slots back
    to original edge indices (-1 for inert slots); ``v_lo`` gives the
    contiguous vertex blocks ``[v_lo[s], v_lo[s+1])``.
    """

    pgm: PGM                    # original graph (vertex tables, beliefs)
    n: int                      # number of bands == mesh size to run on
    band_len: int               # padded slots per band
    v_lo: np.ndarray            # (n+1,) int64 vertex block boundaries
    edge_src: jax.Array         # (n, L) int32
    edge_dst: jax.Array         # (n, L) int32
    edge_rev: jax.Array         # (n, L) int32, flattened slot coords
    edge_mask: jax.Array        # (n, L) bool
    log_psi_e: jax.Array        # (n, L, S, S) f32
    slot_edge: np.ndarray       # (n, L) int64, original edge id or -1


def partition_banded(pgm: PGM, n: int) -> BandedPartition:
    """Cut ``pgm`` into ``n`` contiguous edge bands for halo-exchange BP.

    Vertices are split into ``n`` contiguous blocks balanced by in-degree;
    each band is the (stable dst-sorted) slice of directed edges pointing
    into one block. Asserts the **banded contract**: every real edge must
    connect vertices in the same or adjacent blocks, so one band of halo on
    each side covers all remote reads. Chains and row-major grids pass for
    any reasonable ``n``; irregular spatial graphs (e.g.
    ``protein_like_graph``) fail the assert and must use the general
    ``run_bp_sharded`` path instead.
    """
    # Contract violations raise AssertionError explicitly (not via the
    # `assert` statement): rejection is API behavior -- silently accepting
    # a non-banded graph under `python -O` would compute wrong beliefs.
    if n < 1:
        raise AssertionError(f"need n >= 1 bands, got {n}")
    src = np.asarray(pgm.edge_src)
    dst = np.asarray(pgm.edge_dst)
    rev = np.asarray(pgm.edge_rev)
    mask = np.asarray(pgm.edge_mask)
    nv = pgm.n_real_vertices
    real = np.flatnonzero(mask)
    if real.size == 0:
        raise AssertionError("empty graph")
    # Global stable destination order: every vertex's incoming edges stay in
    # their original relative order (the round-exactness invariant).
    order = real[np.argsort(dst[real], kind="stable")]
    e_real = order.size

    # Vertex blocks [v_lo[s], v_lo[s+1]) balanced by in-degree.
    indeg = np.bincount(dst[order], minlength=nv)
    cum0 = np.concatenate([[0], np.cumsum(indeg)])          # (nv+1,)
    targets = np.arange(1, n) * (e_real / n)
    cuts = np.searchsorted(cum0[1:], targets, side="left") + 1
    v_lo = np.concatenate([[0], np.clip(cuts, 0, nv), [nv]])
    v_lo = np.maximum.accumulate(v_lo)
    block = np.searchsorted(v_lo, np.arange(nv), side="right") - 1  # (nv,)

    # The banded contract: edges never skip over a block.
    span = np.abs(block[src[order]] - block[dst[order]])
    if int(span.max(initial=0)) > 1:
        raise AssertionError(
            f"graph is not banded for n={n}: an edge spans "
            f"{int(span.max())} vertex blocks (> 1); re-order vertices or "
            "use run_bp_sharded")

    # Band s = sorted positions [p_lo[s], p_lo[s+1]).
    p_lo = cum0[v_lo]                                       # (n+1,)
    band_len = max(int(np.max(p_lo[1:] - p_lo[:-1])), 1)

    # Slot of each sorted position: band s, offset p - p_lo[s].
    pos_band = np.searchsorted(p_lo, np.arange(e_real), side="right") - 1
    pos_slot = pos_band * band_len + (np.arange(e_real) - p_lo[pos_band])
    slot_of = np.full(pgm.n_edges, -1, dtype=np.int64)
    slot_of[order] = pos_slot

    dummy = nv
    total = n * band_len
    b_src = np.full(total, dummy, dtype=np.int32)
    b_dst = np.full(total, dummy, dtype=np.int32)
    b_rev = np.arange(total, dtype=np.int32)                # inert: self
    b_mask = np.zeros(total, dtype=bool)
    s_pad = pgm.n_states_max
    b_psi = np.zeros((total, s_pad, s_pad), dtype=np.float32)
    slot_edge = np.full(total, -1, dtype=np.int64)

    b_src[pos_slot] = src[order]
    b_dst[pos_slot] = dst[order]
    b_rev[pos_slot] = slot_of[rev[order]]
    b_mask[pos_slot] = True
    b_psi[pos_slot] = np.asarray(pgm.log_psi_e)[order]
    slot_edge[pos_slot] = order

    # Reverse edges stay within one band of halo (implied by the contract;
    # kept as a hard invariant because the runner indexes the halo window).
    rev_band = b_rev[pos_slot] // band_len
    if int(np.abs(rev_band - pos_band).max(initial=0)) > 1:
        raise AssertionError("reverse edge escaped the one-band halo")

    shape = (n, band_len)
    return BandedPartition(
        pgm=pgm, n=n, band_len=band_len, v_lo=v_lo,
        edge_src=jnp.asarray(b_src.reshape(shape)),
        edge_dst=jnp.asarray(b_dst.reshape(shape)),
        edge_rev=jnp.asarray(b_rev.reshape(shape)),
        edge_mask=jnp.asarray(b_mask.reshape(shape)),
        log_psi_e=jnp.asarray(b_psi.reshape(shape + (s_pad, s_pad))),
        slot_edge=slot_edge.reshape(shape))


def _halo_ext(x: jax.Array, axis: str, n: int) -> jax.Array:
    """Concatenate [left band | own band | right band] along axis 0 via two
    neighbor ppermutes. Boundary shards see zeros in the missing side --
    always masked inert by the ext edge metadata."""
    left = jax.lax.ppermute(x, axis, [(i, i + 1) for i in range(n - 1)])
    right = jax.lax.ppermute(x, axis, [(i + 1, i) for i in range(n - 1)])
    return jnp.concatenate([left, x, right], axis=0)


# Compiled-loop cache: the shard_map'd while_loop is rebuilt per
# (partition, mesh, scheduler, eps, max_rounds, damping) tuple; caching by
# partition identity (strong ref keeps ids stable) lets repeated calls --
# serving, benchmarking -- reuse the jit cache instead of retracing. FIFO-
# bounded so a long-lived process churning partitions cannot hoard edge
# tables and executables without limit.
_RUNNER_CACHE: "dict" = {}
_RUNNER_CACHE_MAX = 16


def run_bp_banded(part: BandedPartition, scheduler, mesh: Mesh,
                  rng: jax.Array, *, eps: float = 1e-3,
                  max_rounds: int = 2000, damping: float = 0.0):
    """Frontier BP over ``mesh`` with one band per device and neighbor-only
    halo exchange; returns ``(logm, rounds, done)``.

    ``logm`` is ``(E, S) f32`` final messages in the *original* pgm edge
    layout (inert padded edges keep their init values, exactly like the
    single-device loop); ``rounds`` is the () int32 count of committed
    sweeps and ``done`` the () bool convergence flag -- True iff every real
    edge's residual fell below ``eps`` within ``max_rounds``. ``scheduler``
    may be ``LBP()`` (round-exact vs the single-device reference, see module
    docstring), ``RnBP(...)`` / ``RLX(...)`` / ``RLXTree(...)`` (per-shard
    RNG streams), or a registry spec string for any of them; exact
    sort-based schedulers raise ``NotImplementedError`` carrying the
    uniform registry message that names the supported subset
    (``BANDED_SCHEDULERS``).
    """
    if isinstance(scheduler, str):
        scheduler = get_scheduler(scheduler)
    if not isinstance(scheduler, tuple(BANDED_SCHEDULERS.values())):
        raise NotImplementedError(
            f"{type(scheduler).__name__} needs a global sort per round "
            "(use run_bp_sharded); "
            + BANDED_SCHEDULERS.unknown(type(scheduler).__name__.lower()))
    if scheduler.inner_sweeps != 1:
        raise NotImplementedError(
            f"inner_sweeps={scheduler.inner_sweeps}: the banded loop runs "
            "one sweep per round; !=1 would break round parity with the "
            "engine")
    key = (id(part), mesh, scheduler, eps, max_rounds, damping)
    if key in _RUNNER_CACHE:
        _, runner = _RUNNER_CACHE[key]
        return runner(rng)
    n, L = part.n, part.band_len
    axis = mesh.axis_names[0]
    if mesh.shape[axis] != n:
        raise AssertionError(
            f"partition has {n} bands but mesh axis {axis!r} has "
            f"{mesh.shape[axis]} devices")
    pgm = part.pgm
    nvert = pgm.n_vertices
    e_real = int(np.asarray(part.edge_mask).sum())

    # Static halo-extended edge metadata: band s sees [s-1 | s | s+1].
    def ext3(a: np.ndarray, fill) -> np.ndarray:
        pad = np.full((1,) + a.shape[1:], fill, a.dtype)
        return np.concatenate(
            [np.concatenate([pad, a[:-1]]), a,
             np.concatenate([a[1:], pad])], axis=1)

    dst_np = np.asarray(part.edge_dst)
    mask_np = np.asarray(part.edge_mask)
    ext_dst = jnp.asarray(ext3(dst_np, pgm.n_real_vertices))   # (n, 3L)
    ext_mask = jnp.asarray(ext3(mask_np, False))               # (n, 3L)

    rnbp = isinstance(scheduler, RnBP)
    relaxed = isinstance(scheduler, (RLX, RLXTree))
    if relaxed:
        # Band slots are already in stable destination order, so contiguous
        # band-local queues realize both rlx (storage-contiguous) and
        # rlxtree (dst-ordered) partitions at once. `queues` is the global
        # relaxation degree: each of the n shards hosts its share, and the
        # per-queue k divides the global frontier budget p*|E| over all
        # queues. Selection is entirely shard-local.
        q_band = queue_count(L, max(1, scheduler.queues // n))
        k_band = min(max(1, int(round(
            scheduler.p * e_real / (q_band * n)))), L // q_band)

    def body_shard(src, dst, rev, emask, psi_e, xdst, xmask, psi_v, smask,
                   key_data):
        (src, dst, rev, emask, xdst, xmask) = (
            a.reshape(a.shape[1:]) for a in (src, dst, rev, emask, xdst,
                                             xmask))
        psi_e = psi_e.reshape(psi_e.shape[1:])
        idx = jax.lax.axis_index(axis)
        base = (idx - 1) * L            # flattened coord of ext slot 0
        shard_key = jax.random.fold_in(
            jax.random.wrap_key_data(key_data), idx)
        logm0 = jnp.where(smask[dst], -jnp.log(
            pgm.n_states[dst].astype(jnp.float32))[:, None], NEG_INF)

        def cond(c):
            logm, rounds, done, old_count, k = c
            return (~done) & (rounds < max_rounds)

        def bp_round(c):
            logm, rounds, done, old_count, k = c
            k, sel_key = jax.random.split(k)
            ext_logm = _halo_ext(logm, axis, n)               # (3L, S)
            # Incoming sums for every vertex the band touches: the ext
            # window holds ALL incoming edges of any src/dst of an owned
            # edge (banded contract), in global stable order.
            contrib = jnp.where(xmask[:, None], ext_logm, 0.0)
            vsum = jax.ops.segment_sum(contrib, xdst, num_segments=nvert)
            pre = psi_v[src] + vsum[src] - ext_logm[rev - base]
            pre = jnp.where(smask[src], pre, NEG_INF)
            cand = M.propagate_ref(psi_e, pre)
            cand, resid = M.normalize_and_residual(cand, logm, smask[dst],
                                                   emask)
            unconverged = jax.lax.psum(
                jnp.sum((resid >= eps) & emask).astype(jnp.int32), axis)
            if rnbp:
                new_count = unconverged.astype(jnp.float32)
                ratio = new_count / jnp.maximum(old_count, 1.0)
                p = jnp.where(ratio > scheduler.ratio_threshold,
                              scheduler.low_p, scheduler.high_p)
                keep = jax.random.uniform(sel_key, resid.shape) < p
                frontier = (resid >= eps) & emask & keep
                old_count = new_count
            elif relaxed:
                # Per-queue top-k of a sampled queue subset, shard-local;
                # each shard force-includes its own max-residual queue
                # (relaxed_frontier), so the shard holding the global max
                # always commits it -- no livelock, no cross-shard sort.
                res2 = jnp.where(emask, resid, 0.0).reshape(
                    q_band, L // q_band)
                frontier = relaxed_frontier(
                    res2, k_band, scheduler.sample, sel_key).reshape(L)
            else:
                frontier = emask
            newly_done = unconverged == 0
            frontier = frontier & ~newly_done
            if damping > 0.0:
                cand = (1.0 - damping) * cand + damping * logm
            logm = jnp.where(frontier[:, None], cand, logm)
            rounds = rounds + jnp.where(newly_done, 0, 1)
            return (logm, rounds, newly_done, old_count, k)

        init = (logm0, jnp.int32(0), jnp.asarray(False),
                jnp.float32(e_real), shard_key)
        logm, rounds, done, _, _ = jax.lax.while_loop(cond, bp_round, init)
        return logm, rounds, done

    sharded = jax.jit(shard_map(
        body_shard, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis),
                  P(axis), P(axis), P(None, None), P(None, None), P()),
        out_specs=(P(axis, None), P(), P()),
        check_rep=False))
    slots = part.slot_edge.reshape(-1)
    live = np.flatnonzero(slots >= 0)

    def runner(rng):
        logm_bands, rounds, done = sharded(
            part.edge_src, part.edge_dst, part.edge_rev, part.edge_mask,
            part.log_psi_e, ext_dst, ext_mask, pgm.log_psi_v,
            pgm.state_mask, jax.random.key_data(rng))
        # Scatter band slots back to the original edge layout; untouched
        # padded edges keep their init values, like the single-device loop.
        flat = logm_bands.reshape(n * L, -1)
        logm = M.init_messages(pgm).at[slots[live]].set(flat[live])
        return logm, rounds, done

    if len(_RUNNER_CACHE) >= _RUNNER_CACHE_MAX:
        _RUNNER_CACHE.pop(next(iter(_RUNNER_CACHE)))   # FIFO eviction
    _RUNNER_CACHE[key] = (part, runner)   # strong ref pins id(part)
    return runner(rng)


__all__ = ["BANDED_SCHEDULERS", "BandedPartition", "partition_banded",
           "run_bp_banded"]
