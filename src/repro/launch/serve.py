"""Serving launcher: prefill + batched greedy decode through the production
sharding path (reduced configs on CPU; same lowering as the dry-run cells).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_4b --reduced \
      --batch 4 --prompt-len 16 --gen 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args()

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    b, s = args.batch, args.prompt_len
    total = s + args.gen
    toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.frontend == "audio":
        batch["frontend_embeds"] = 0.1 * jax.random.normal(
            jax.random.key(2), (b, s, cfg.d_model), jnp.float32)
        batch["tokens"] = toks[:, :1]
    if cfg.frontend == "vision":
        batch["frontend_embeds"] = 0.1 * jax.random.normal(
            jax.random.key(2), (b, cfg.n_frontend_tokens, cfg.d_model),
            jnp.float32)

    decode = jax.jit(model.decode_step)
    t0 = time.perf_counter()
    # prefill token-by-token into the serve-length cache (cache-correct path;
    # a production deployment fuses this with model.prefill + cache copy)
    cache = model.init_cache(b, total)
    out = []
    pos = 0
    prompt = batch["tokens"]
    for t in range(prompt.shape[1]):
        logits, cache = decode(params, cache, prompt[:, t:t + 1],
                               jnp.int32(pos))
        pos += 1
    nxt = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out.append(nxt)
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, nxt, jnp.int32(pos))
        nxt = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(nxt)
        pos += 1
    gen = jnp.concatenate(out, axis=1)
    dt = time.perf_counter() - t0
    print(f"{cfg.name}: generated {gen.shape} in {dt:.2f}s "
          f"({b * args.gen / dt:.1f} tok/s)")
    print("sample:", gen[0].tolist())


if __name__ == "__main__":
    main()
