import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices stand in for 2 TPU v5e pods.
For each cell we:

    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...)\
                      .lower(**input_specs(arch, shape))
        compiled = lowered.compile()
        memory_analysis / cost_analysis / collective-bytes -> JSON

Cells: the 10 assigned archs x their shapes (long_500k only for
sub-quadratic archs -- see DESIGN.md), plus the BP workload itself
(`bp_ising`, `bp_chain`) so the paper's contribution goes through the same
production meshes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_4b --shape train_4k
Outputs one JSON per cell under experiments/dryrun/.
"""

import argparse
import json
import sys
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get
from repro.data.pipeline import make_batch_specs
from repro.launch.mesh import (data_axes, make_production_mesh, model_size)
from repro.launch.sharding import (batch_shardings, cache_shardings,
                                   param_shardings, replicated,
                                   train_state_shardings)
from repro.models import build_model
from repro.roofline import analyze_compiled, model_flops
from repro.roofline.jaxpr_cost import trace_cost
from repro.train.step import make_train_step, train_state_specs


def _tree_bytes(tree) -> float:
    import numpy as np
    return float(sum(np.prod(l.shape, dtype=np.float64)
                     * np.dtype(l.dtype).itemsize
                     for l in jax.tree.leaves(tree)))

BP_CELLS = ("bp_ising_512", "bp_chain_1m", "bp_ising_512_banded",
            "bp_chain_1m_banded")


def _spec_tokens(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg, shape):
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    model = build_model(cfg)
    if shape.kind == "train":
        return {"batch": make_batch_specs(cfg, shape)}
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "prefill":
        batch = {}
        if cfg.frontend == "vision":
            t = cfg.n_frontend_tokens
            batch["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, t, cfg.d_model), jnp.bfloat16)
            batch["tokens"] = _spec_tokens(b, s - t)
        elif cfg.frontend == "audio":
            batch["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, s, cfg.d_model), jnp.bfloat16)
            batch["tokens"] = _spec_tokens(b, 1)   # decoder BOS
        else:
            batch["tokens"] = _spec_tokens(b, s)
        return {"batch": batch}
    # decode: one token against a seq_len cache
    return {"cache": model.init_cache_specs(b, s),
            "tokens": _spec_tokens(b, 1),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def lower_cell(arch: str, shape_name: str, mesh, *,
               microbatches: int = 1, remat: bool = True,
               sharding_mode: str = "tp", moe_dispatch: str = ""):
    """Returns (lowered, compiled, meta) for one cell."""
    import dataclasses as _dc
    cfg = get(arch)
    if moe_dispatch and cfg.n_experts:
        cfg = _dc.replace(cfg, moe_dispatch=moe_dispatch)
        if moe_dispatch == "sharded":
            from repro.models.layers.moe import set_shard_mesh
            set_shard_mesh(mesh)
    shape = next(s for s in cfg.shapes() if s.name == shape_name)
    act_spec = None
    if sharding_mode == "fsdp":
        b = shape.global_batch
        fsdp = mesh.shape["data"] * mesh.shape["model"]
        if b % fsdp == 0 and b >= fsdp:
            act_spec = P(("data", "model"), None, None)
    model = build_model(cfg)
    model.act_spec = act_spec
    specs = input_specs(cfg, shape)
    n_dev = mesh.devices.size

    with mesh:
        if shape.kind == "train":
            state_specs = train_state_specs(model)
            state_sh = train_state_shardings(mesh, state_specs,
                                             mode=sharding_mode)
            step = make_train_step(
                model, microbatches=microbatches, remat=remat,
                grad_shardings=(state_sh.params
                                if sharding_mode == "fsdp" else None))
            batch_sh = batch_shardings(mesh, specs["batch"],
                                       mode=sharding_mode)
            fn = jax.jit(step,
                         in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None))
            lowered = fn.lower(state_specs, specs["batch"])
            logical = trace_cost(step, state_specs, specs["batch"])
            param_bytes = _tree_bytes(state_specs.params)
            n_tokens = shape.global_batch * shape.seq_len
            kind = "train"
        elif shape.kind == "prefill":
            p_specs = model.param_specs()
            p_sh = param_shardings(mesh, p_specs)
            batch_sh = batch_shardings(mesh, specs["batch"])
            fn = jax.jit(model.prefill, in_shardings=(p_sh, batch_sh),
                         out_shardings=None)
            lowered = fn.lower(p_specs, specs["batch"])
            logical = trace_cost(model.prefill, p_specs, specs["batch"])
            param_bytes = _tree_bytes(p_specs)
            n_tokens = shape.global_batch * shape.seq_len
            kind = "prefill"
        else:
            p_specs = model.param_specs()
            p_sh = param_shardings(mesh, p_specs)
            cache_sh = cache_shardings(mesh, specs["cache"])
            tok_sh = batch_shardings(mesh, specs["tokens"])
            fn = jax.jit(model.decode_step,
                         in_shardings=(p_sh, cache_sh, tok_sh,
                                       NamedSharding(mesh, P())),
                         out_shardings=(None, cache_sh))
            lowered = fn.lower(p_specs, specs["cache"], specs["tokens"],
                               specs["pos"])
            logical = trace_cost(model.decode_step, p_specs, specs["cache"],
                                 specs["tokens"], specs["pos"])
            param_bytes = _tree_bytes(p_specs)
            n_tokens = shape.global_batch  # one token per sequence
            kind = "decode"
        compiled = lowered.compile()

    mf = model_flops(model.param_specs(), n_tokens, cfg=cfg, kind=kind)
    # fsdp: params are gathered whole per layer -> per-device param traffic
    # ~= full param bytes (model_axis divisor does not apply)
    m_axis = 1 if sharding_mode == "fsdp" else model_size(mesh)
    return lowered, compiled, {"model_flops": mf, "n_devices": n_dev,
                               "kind": kind, "logical": logical,
                               "param_bytes": param_bytes,
                               "model_axis": m_axis}


def lower_bp_cell(name: str, mesh):
    """BP workload cells through the same production mesh (flattened to a
    1-D 'bp' axis view via the mesh's devices)."""
    from repro.core import RnBP
    from repro.dist.bp_shard import partition_pgm, run_bp_sharded
    from repro.pgm import chain_graph, ising_grid_fast

    n_dev = mesh.devices.size
    bp_mesh = jax.make_mesh((n_dev,), ("bp",),
                            devices=mesh.devices.reshape(-1))
    if "ising" in name:
        pgm = ising_grid_fast(512, 2.5, seed=0)
    else:
        pgm = chain_graph(1_000_000, C=10.0, seed=0)
    sched = RnBP(low_p=0.7)

    if name.endswith("_banded"):
        from repro.dist.bp_banded import partition_banded, run_bp_banded
        part = partition_banded(pgm, n_dev)

        def bp_step(part_arrs, rng):
            return run_bp_banded(part_arrs, sched, bp_mesh, rng,
                                 eps=1e-3, max_rounds=100)

        # run_bp_banded takes the dataclass; trace via a thin wrapper over
        # its jnp arrays
        import dataclasses as _dc

        def bp_step2(arr_dict, rng):
            p2 = _dc.replace(part, **{k: v for k, v in arr_dict.items()})
            return run_bp_banded(p2, sched, bp_mesh, rng, eps=1e-3,
                                 max_rounds=100)

        arr_keys = ("src_l", "dst_l", "rev_l", "emask", "log_psi_e",
                    "log_psi_v", "smask_v", "n_states_v")
        arrs = {k: jnp.asarray(getattr(part, k)) for k in arr_keys}
        specs = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), arrs)
        with bp_mesh:
            lowered = jax.jit(bp_step2).lower(specs, jax.random.key(0))
            compiled = lowered.compile()
            logical = trace_cost(bp_step2, specs, jax.random.key(0),
                                 while_trips=100.0)
        e, s = pgm.n_real_edges, pgm.n_states_max
        mf = 100 * e * (4 * s * s + 6 * s)
        return lowered, compiled, {"model_flops": float(mf),
                                   "n_devices": n_dev, "kind": "bp",
                                   "logical": logical, "param_bytes": 0.0,
                                   "model_axis": 1}

    def bp_step(pgm_in, rng):
        return run_bp_sharded(pgm_in, sched, bp_mesh, rng, eps=1e-3,
                              max_rounds=100)

    pgm = partition_pgm(pgm, n_dev)
    pgm_specs = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), pgm)
    rng_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    with bp_mesh:
        lowered = jax.jit(bp_step).lower(
            pgm_specs, jax.random.key(0))
        compiled = lowered.compile()
        logical = trace_cost(bp_step, pgm_specs, jax.random.key(0),
                             while_trips=100.0)
    # BP "model flops": one message pass = E * S^2 * ~4 flops x rounds(=100)
    e, s = pgm.n_real_edges, pgm.n_states_max
    mf = 100 * e * (4 * s * s + 6 * s)
    return lowered, compiled, {"model_flops": float(mf),
                               "n_devices": n_dev, "kind": "bp",
                               "logical": logical, "param_bytes": 0.0,
                               "model_axis": 1}


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             *, microbatches: int = 1, quiet: bool = False,
             sharding_mode: str = "tp", tag: str = "",
             moe_dispatch: str = "") -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        if arch.startswith("bp_"):
            lowered, compiled, meta = lower_bp_cell(arch, mesh)
        else:
            lowered, compiled, meta = lower_cell(
                arch, shape_name, mesh, microbatches=microbatches,
                sharding_mode=sharding_mode, moe_dispatch=moe_dispatch)
        report = analyze_compiled(
            compiled, n_devices=meta["n_devices"],
            logical_flops=meta["logical"].flops,
            logical_bytes=meta["logical"].bytes,
            param_bytes=meta["param_bytes"],
            model_axis=meta["model_axis"],
            model_flops_global=meta["model_flops"])
        rec = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "ok", "kind": meta["kind"],
            "compile_s": round(time.time() - t0, 1),
            **report.as_dict(),
        }
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "FAIL", "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(out_dir,
                        f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if not quiet:
        if rec["status"] == "ok":
            mem = rec.get("memory_per_device") or {}
            print(f"[ok] {arch:22s} {shape_name:12s} {mesh_name:8s} "
                  f"flops/dev={rec['flops']:.3e} bytes/dev={rec['hbm_bytes']:.3e} "
                  f"coll/dev={rec['coll_bytes']:.3e} bn={rec['bottleneck']:10s} "
                  f"useful={rec['useful_ratio']:.2f} "
                  f"tmp={mem.get('temp_bytes', -1):.2e} "
                  f"t={rec['compile_s']}s", flush=True)
        else:
            print(f"[FAIL] {arch} {shape_name} {mesh_name}: {rec['error']}",
                  flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--sharding", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--moe-dispatch", default="",
                    choices=["", "ragged", "dense", "sharded"])
    args = ap.parse_args()

    archs = list(ARCH_IDS) + list(BP_CELLS) if args.arch == "all" \
        else args.arch.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    n_fail = 0
    for arch in archs:
        if arch.startswith("bp_"):
            shapes = ["-"]
        else:
            cfg = get(arch)
            shapes = [s.name for s in cfg.shapes()] if args.shape == "all" \
                else args.shape.split(",")
        for shape_name in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape_name, mp, args.out,
                               microbatches=args.microbatches,
                               sharding_mode=args.sharding, tag=args.tag,
                               moe_dispatch=args.moe_dispatch)
                n_fail += rec["status"] != "ok"
    print(f"dry-run complete; failures: {n_fail}")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
