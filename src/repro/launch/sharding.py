"""Sharding rules: parameters, optimizer state, batches, KV/SSM caches.

Divisibility-aware resolver: a dimension is sharded over "model" only when
divisible by the axis size; otherwise the rule degrades to replication for
that leaf (correct, just less parallel -- e.g. hymba's 25 attention heads or
whisper's 51865-token vocab). Batch dims shard over ("pod","data") when
divisible (always true for the assigned shapes except long_500k's batch=1,
which replicates batch and relies on sequence/model parallelism).

Megatron-style defaults:
  column-parallel (shard output dim):  wq/wk/wv/w_in/w_gate/w_uq/... ,
  row-parallel    (shard input  dim):  wo/w_out/shared_w_out/proj ,
  MoE experts: tensor-parallel on d_ff (all experts resident per device,
  no all-to-all; see repro.models.layers.moe docstring),
  embeddings: vocab-sharded when divisible,
  KV caches: *sequence*-sharded over "model" (flash-decoding style -- the
  softmax over the sharded key axis becomes a tiny all-reduce of per-shard
  max/sum instead of an all-gather of the cache).
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import data_axes, model_size

ROW_PARALLEL = {"wo", "w_out", "shared_w_out", "proj"}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "name"):
            return str(entry.name)
    return ""


def _param_pspec(path, leaf, mp: int, stacked: bool) -> P:
    """PartitionSpec for one parameter leaf. ``stacked`` strips a leading
    layer axis (scan-stacked blocks)."""
    name = _leaf_name(path)
    shape = leaf.shape[1:] if stacked else leaf.shape
    nd = len(shape)
    lead = (None,) if stacked else ()

    def ok(d):
        return shape[d] % mp == 0 and shape[d] >= mp

    if nd <= 1:
        return P(*lead, *([None] * nd))
    if name == "table":                       # embedding / lm head
        if ok(0):
            return P(*lead, "model", None)
        return P(*lead, None, "model") if ok(1) else P(*lead, None, None)
    if nd == 3:                               # MoE expert stacks (E, a, b)
        if name in ROW_PARALLEL:
            return P(*lead, None, "model", None) if ok(1) \
                else P(*lead, None, None, None)
        return P(*lead, None, None, "model") if ok(2) \
            else P(*lead, None, None, None)
    if nd == 2:
        if name in ROW_PARALLEL:
            return P(*lead, "model", None) if ok(0) else P(*lead, None, None)
        return P(*lead, None, "model") if ok(1) else P(*lead, None, None)
    return P(*lead, *([None] * nd))


def _fsdp_pspec(path, leaf, axes: tuple, axes_size: int,
                stacked: bool) -> P:
    """ZeRO-3: shard every parameter on its largest divisible trailing dim
    over the flattened (data, model) axes; no tensor parallelism, so layers
    run collective-free and the only collectives are per-layer param
    all-gathers (bf16) + gradient reduce-scatters."""
    shape = leaf.shape[1:] if stacked else leaf.shape
    lead = (None,) if stacked else ()
    if not shape:
        return P(*lead)
    # small leaves (norms, biases): gathering them 256-wide costs more in
    # resharding churn than replication costs in memory -> replicate
    n_elems = 1
    for d in shape:
        n_elems *= d
    if n_elems < (1 << 20):
        return P(*lead, *([None] * len(shape)))
    name = _leaf_name(path)
    if name == "table":               # embeddings: shard vocab rows
        dims = list(range(len(shape)))
    else:
        # prefer the OUTPUT (last) dim: sharding the contracting dim would
        # turn every x@W into a partial-sum + activation-sized psum (seen:
        # 19 TB/step of per-layer all-reduce on mistral -- SSPerf iter 2)
        dims = list(range(len(shape) - 1, -1, -1))
    for d in dims:
        if shape[d] % axes_size == 0 and shape[d] >= axes_size:
            spec = [None] * len(shape)
            spec[d] = axes
            return P(*lead, *spec)
    return P(*lead, *([None] * len(shape)))


def param_shardings(mesh: Mesh, param_specs: Any, mode: str = "tp"):
    """NamedSharding pytree matching a params (or ShapeDtypeStruct) tree.

    mode="tp": megatron tensor-parallel over "model" (baseline).
    mode="fsdp": ZeRO-3 over flattened ("data","model") -- see SSPerf."""
    mp = model_size(mesh)
    fsdp_axes = ("data", "model")
    fsdp_size = mesh.shape["data"] * mesh.shape["model"]

    def rule(path, leaf):
        stacked = any("blocks" in _key_str(e) for e in path)
        if mode == "fsdp":
            return NamedSharding(mesh, _fsdp_pspec(path, leaf, fsdp_axes,
                                                   fsdp_size, stacked))
        return NamedSharding(mesh, _param_pspec(path, leaf, mp, stacked))

    return jax.tree_util.tree_map_with_path(rule, param_specs)


def _key_str(entry) -> str:
    return str(getattr(entry, "key", getattr(entry, "name", "")))


def train_state_shardings(mesh: Mesh, state_specs: Any, mode: str = "tp"):
    """TrainState: params + AdamW moments share the param rules; scalars
    replicate."""
    mp = model_size(mesh)
    fsdp_axes = ("data", "model")
    fsdp_size = mesh.shape["data"] * mesh.shape["model"]

    def rule(path, leaf):
        names = [_key_str(e) for e in path]
        if leaf.ndim == 0 or "count" in names or "step" in names:
            return NamedSharding(mesh, P())
        stacked = any("blocks" in n for n in names)
        if mode == "fsdp":
            return NamedSharding(mesh, _fsdp_pspec(path, leaf, fsdp_axes,
                                                   fsdp_size, stacked))
        return NamedSharding(mesh, _param_pspec(path, leaf, mp, stacked))

    return jax.tree_util.tree_map_with_path(rule, state_specs)


def batch_shardings(mesh: Mesh, batch_specs: Any, mode: str = "tp"):
    """tokens/labels (B, S) -> P(dp, None); frontend (B, T, d) likewise.
    mode="fsdp": batch shards over ("data","model") (+"pod" when divisible)
    since no axis carries tensor parallelism."""
    if mode == "fsdp":
        dp = tuple(mesh.axis_names)  # ("pod",)?+("data","model")
    else:
        dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if mode == "fsdp":
        # try widest first, fall back to ("data","model")
        alt = ("data", "model")
        alt_size = mesh.shape["data"] * mesh.shape["model"]

    def rule(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        b = leaf.shape[0]
        first = dp if (b % dp_size == 0 and b >= dp_size) else None
        if first is None and mode == "fsdp" and b % alt_size == 0 \
                and b >= alt_size:
            first = alt
        return NamedSharding(mesh, P(first, *([None] * (leaf.ndim - 1))))

    return jax.tree_util.tree_map_with_path(rule, batch_specs)


def cache_shardings(mesh: Mesh, cache_specs: Any):
    """Decode caches. Leaves are (L, B, ...) stacked:
      k/v/c_kv/k_rope/cross_*: (L, B, S, ...) -> seq on "model", B on data
      ssm state (L, B, H, P, N): head-dim P on "model" when divisible
      conv/pos: batch-sharded only / replicated."""
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    mp = model_size(mesh)

    def rule(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        if name == "pos" or leaf.ndim <= 1:
            return NamedSharding(mesh, P())
        bdim = dp if (shape[1] % dp_size == 0 and shape[1] >= dp_size) \
            else None
        if name in ("k", "v", "c_kv", "k_rope", "cross_k", "cross_v"):
            sdim = "model" if shape[2] % mp == 0 and shape[2] >= mp else None
            rest = [None] * (leaf.ndim - 3)
            return NamedSharding(mesh, P(None, bdim, sdim, *rest))
        if name == "ssm":                       # (L, B, H, P, N)
            if shape[2] % mp == 0 and shape[2] >= mp:
                return NamedSharding(mesh, P(None, bdim, "model", None, None))
            if shape[3] % mp == 0 and shape[3] >= mp:
                return NamedSharding(mesh, P(None, bdim, None, "model", None))
            return NamedSharding(mesh, P(None, bdim, None, None, None))
        if name == "conv":                      # (L, B, K-1, C)
            return NamedSharding(mesh, P(None, bdim, None, None))
        return NamedSharding(mesh, P(None, bdim,
                                     *([None] * (leaf.ndim - 2))))

    return jax.tree_util.tree_map_with_path(rule, cache_specs)


def replicated(mesh: Mesh, tree: Any):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
