"""Production mesh factories.

Functions, not module-level constants: importing this module never touches
jax device state (jax locks the platform/device count on first use, and the
dry-run needs to set XLA_FLAGS before that happens).

Production target: TPU v5e pods, 256 chips (16 x 16) per pod; the multi-pod
mesh prepends a "pod" axis (2 x 16 x 16 = 512 chips). "data" carries batch
(and sequence for the long-context cells), "model" carries tensor/expert
parallelism. The BP workload flattens the whole mesh into one "bp" axis
(edge-parallel; see repro.dist).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """Axes that carry the batch dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_size(mesh) -> int:
    out = 1
    for a in data_axes(mesh):
        out *= mesh.shape[a]
    return out


def model_size(mesh) -> int:
    return mesh.shape["model"]
