"""Production training launcher.

On a real cluster this binary runs once per host under the pod scheduler
(GKE/XPK); jax.distributed handles cross-host init. In this container it
drives the same code on CPU with reduced configs.

Features exercised: elastic mesh construction, sharded train step,
checkpoint/restore with exact data-cursor resume, straggler monitoring,
cosine LR, microbatch gradient accumulation.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3_4b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore_pytree, save_pytree
from repro.configs import get
from repro.configs.base import TRAIN_4K
from repro.data import SyntheticLM
from repro.ft import ElasticMesh, StragglerMonitor
from repro.launch.sharding import batch_shardings, train_state_shardings
from repro.models import build_model
from repro.train.step import (init_train_state, make_train_step,
                              train_state_specs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", type=str, default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    shape = dataclasses.replace(TRAIN_4K, seq_len=args.seq,
                                global_batch=args.batch)
    pipe = SyntheticLM(cfg, shape)

    elastic = ElasticMesh(model_parallel=args.model_parallel)
    mesh = elastic.current()
    monitor = StragglerMonitor()
    step_fn = make_train_step(model, base_lr=args.lr, warmup=10,
                              total_steps=args.steps,
                              microbatches=args.microbatches)

    with mesh:
        state_sh = train_state_shardings(mesh, train_state_specs(model))
        jit_step = jax.jit(step_fn, in_shardings=(state_sh, None),
                           out_shardings=(state_sh, None))
        state = init_train_state(model, jax.random.key(0))
        start = 0
        if args.ckpt_dir and (s := latest_step(args.ckpt_dir)) is not None:
            like = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            state, extra = restore_pytree(args.ckpt_dir, s, like,
                                          sharding_tree=state_sh)
            start = extra["data_step"]
            print(f"resumed from step {start}", flush=True)

        for i in range(start, args.steps):
            t0 = time.perf_counter()
            state, metrics = jit_step(state, pipe.batch(i))
            jax.block_until_ready(state.step)
            straggler = monitor.record(time.perf_counter() - t0)
            if i % args.log_every == 0 or i == args.steps - 1:
                print(f"step {i:5d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"dt={monitor.ewma:.2f}s"
                      + (" [straggler]" if straggler else ""), flush=True)
            if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                save_pytree(args.ckpt_dir, i + 1, state,
                            extra={"data_step": i + 1})
        print(f"done; straggler events: {monitor.events}")


if __name__ == "__main__":
    main()
