"""repro.serve: the router/replica serving tier -- N ``ServingPipeline``
replicas behind one request front-end.

PRs 4-6 made a *single* serving pipeline performant (async double-buffered
slots, pluggable admission, threaded ingestion, relaxed schedulers); this
package is the tier above it, the "millions of users" rung: a
:class:`Router` consumes one heterogeneous request stream and fans it out
across N :class:`Replica` workers -- each a ``ServingPipeline`` on its own
thread with its own bounded inbox and (optionally) its own engine on a
disjoint device sub-mesh -- then merges the per-request records back into
one completion-order stream with replica attribution and tier-level
p50/p90/p99 latency.

Placement is a pluggable :class:`RoutingPolicy` (``ROUTING_POLICIES``, the
fourth ``repro.core.registry.Registry`` family): ``round_robin`` (the
determinism anchor), ``least_loaded`` (effort-weighted shortest queue, via
the shared thread-safe ``RoundsHistory``), ``kind_affinity`` (sticky
shape placement keeping jit caches hot per replica), ``deadline``
(deadline-aware least-loaded: SLO'd requests avoid replicas already
holding urgent work -- pairs with the ``deadline`` admission policy,
whose evictions surface in ``RoutedRecord.status``). Watermark-triggered
**work stealing** rebalances skew at runtime: a replica whose pending work
drains pulls a batch from the deepest peer's inbox tail. Both are
bitwise-invisible in results -- a request's trajectory depends only on
(rid, padded shape), which no placement decision changes; with
``round_robin`` and stealing off the tier is pinned bitwise-identical to
running each replica's share through ``serve_async`` solo.

Entry points: :func:`serve_routed` (collect everything), :class:`Router`
(incremental generator + context manager). See ``docs/router.md``.
"""

from repro.serve.replica import Replica, ReplicaLoad, RoutedRecord
from repro.serve.router import Router, RouterResult, RouterStats, \
    serve_routed
from repro.serve.routing import (DeadlineRouting, KindAffinityRouting,
                                 LeastLoadedRouting, ROUTING_POLICIES,
                                 RoundRobinRouting, RoutingPolicy,
                                 get_routing_policy, list_routing_policies,
                                 register_routing_policy)

__all__ = [
    "DeadlineRouting", "KindAffinityRouting", "LeastLoadedRouting",
    "ROUTING_POLICIES",
    "Replica", "ReplicaLoad", "RoundRobinRouting", "RoutedRecord",
    "Router", "RouterResult", "RouterStats", "RoutingPolicy",
    "get_routing_policy", "list_routing_policies",
    "register_routing_policy", "serve_routed",
]
