"""Replica: one ``ServingPipeline`` on its own thread behind a bounded
inbound queue.

A :class:`Replica` owns one :class:`~repro.core.serving.ServingPipeline`
(and therefore one ``BPEngine`` -- which may be built on its own sub-mesh,
so replicas can sit on disjoint device slices) plus a bounded :class:`_Inbox`
the router dispatches into. The replica thread drives the pipeline over an
inbox-draining source; every released ``RequestRecord`` is wrapped into a
:class:`RoutedRecord` (replica attribution, routing timeline, steal flag)
and pushed onto the router's shared output queue. :meth:`Replica.load`
returns a :class:`ReplicaLoad` snapshot -- inbox depth, staged width,
effort-in-flight calibrated by the shared
:class:`~repro.core.batch.RoundsHistory` -- which is what routing policies
and the steal trigger read.

Work stealing happens at the inbox boundary, *before* a request is staged:
when this replica's pending work (inbox + feeder buffer + staged) drains
below ``low_watermark``, its source invokes the router's steal hook, which
transplants a batch from the tail of the deepest peer's inbox into this
one. Stolen requests keep their rid (and therefore their
``fold_in(rng, rid)`` key) and pad to the same deterministic
``bucket_shape`` ceilings on either side, so stealing never changes a
result bit -- it only changes *where* the sweeps run.
"""

from __future__ import annotations

import dataclasses
import queue as _queue
import threading
from collections import deque
from typing import Callable, Deque, List, Optional, Tuple

import jax

from repro.core.batch import RoundsHistory
from repro.core.engine import BPEngine
from repro.core.serving import RequestRecord, ServingPipeline
from repro.core.graph import PGM

__all__ = ["Replica", "ReplicaLoad", "RoutedRecord"]

_CLOSED = object()
_EMPTY = object()


@dataclasses.dataclass
class _Request:
    """One routed request in flight: identity, payload, and routing-side
    metadata that must travel with it across steals."""
    rid: int
    pgm: PGM
    kind: Tuple[int, ...]       # bucket_shape ceilings (the shape family)
    t_route: float              # when the router pulled it from the stream
    stolen: bool = False
    deadline: float | None = None   # absolute (router clock); travels with
                                    # the request across steals


@dataclasses.dataclass
class RoutedRecord:
    """One served request with replica attribution: the replica-local
    :class:`~repro.core.serving.RequestRecord` plus which replica ran it,
    its bucket-shape ``kind``, whether it was work-stolen, and ``t_route``
    (when the *router* pulled it from the stream -- the tier-level queue-in,
    earlier than the replica-local ``t_enqueue``)."""

    replica: int
    kind: Tuple[int, ...]
    stolen: bool
    t_route: float
    record: RequestRecord

    @property
    def rid(self) -> int:
        """Request id (the RNG fold_in index)."""
        return self.record.rid

    @property
    def result(self):
        """The request's ``BPResult``."""
        return self.record.result

    @property
    def latency_s(self) -> float:
        """Router queue-in -> result release, seconds (the tier-level
        end-to-end latency; includes routing and replica-inbox wait)."""
        return self.record.t_done - self.t_route

    @property
    def queue_s(self) -> float:
        """Router queue-in -> bucket admission, seconds (routing + inbox +
        admission wait)."""
        return self.record.t_admit - self.t_route

    @property
    def service_s(self) -> float:
        """Time resident in a bucket slot, seconds."""
        return self.record.service_s

    @property
    def status(self) -> str:
        """``"completed"`` or ``"evicted"`` (the replica-local record's
        status -- evicted requests carry partial beliefs)."""
        return self.record.status

    @property
    def evicted(self) -> bool:
        """True when the replica's admission policy gave up on this
        request (deadline eviction); the result is partial."""
        return self.record.evicted

    @property
    def within_slo(self) -> bool:
        """Completed within its latency budget (vacuously true without
        one). Delegates to the replica-local record: the budget the
        replica received already had routing + inbox wait charged against
        it, so this is the tier-level SLO verdict."""
        return self.record.within_slo


@dataclasses.dataclass(frozen=True)
class ReplicaLoad:
    """Point-in-time load snapshot of one replica, the routing policies'
    input: ``inbox`` requests queued before the pipeline, ``staged``
    requests padded/prefetched inside it, ``in_flight`` resident in bucket
    slots, and ``effort`` -- pending depth weighted by expected rounds per
    request from the shared ``RoundsHistory`` (so two heavy requests read
    as more load than three light ones)."""

    replica: int
    inbox: int
    staged: int
    in_flight: int
    effort: float
    urgent: int = 0             # deadlined requests queued in the inbox

    @property
    def depth(self) -> int:
        """Unweighted pending request count (inbox + staged + in_flight)."""
        return self.inbox + self.staged + self.in_flight

    @property
    def weight(self) -> float:
        """What ``least_loaded`` minimizes: the effort-weighted depth."""
        return self.effort


class _Inbox:
    """Bounded, stealable inbound queue (one lock + condition).

    ``put`` blocks while full (backpressure onto the router) unless
    ``force`` -- the steal path, which transplants work that was already
    admitted tier-wide. ``finish`` marks the stream complete: no more
    router puts, pops drain the remainder; ``close`` abandons outright.
    ``steal`` pops up to ``k`` requests from the *tail* (the newest --
    head order, and therefore the victim's own admission order, is
    preserved), never leaving the victim with fewer than ``leave``."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"inbox capacity must be >= 1, got {capacity}")
        self._capacity = capacity
        self._items: Deque[_Request] = deque()
        self._cond = threading.Condition()
        self._done = False
        self._dead = False

    def __len__(self) -> int:
        return len(self._items)

    @property
    def dead(self) -> bool:
        return self._dead

    def kinds(self) -> List[Tuple[int, ...]]:
        """The queued requests' bucket-shape kinds (snapshot)."""
        with self._cond:
            return [r.kind for r in self._items]

    def snapshot(self) -> "List[Tuple[Tuple[int, ...], float | None]]":
        """(kind, absolute deadline) per queued request -- what load
        introspection reads (deadline = None for un-SLO'd requests)."""
        with self._cond:
            return [(r.kind, r.deadline) for r in self._items]

    def put(self, req: _Request, *, force: bool = False) -> None:
        with self._cond:
            while (not force and len(self._items) >= self._capacity
                   and not self._done and not self._dead):
                self._cond.wait(0.05)
            if self._dead or (self._done and not force):
                raise ValueError("replica inbox is closed")
            self._items.append(req)
            self._cond.notify_all()

    def pop(self, timeout: float):
        """Head request, or ``_EMPTY`` after ``timeout`` with nothing
        available, or ``_CLOSED`` once abandoned / finished-and-drained."""
        with self._cond:
            if not self._items and not self._dead:
                self._cond.wait(timeout)
            if self._dead:
                return _CLOSED
            if self._items:
                req = self._items.popleft()
                self._cond.notify_all()
                return req
            return _CLOSED if self._done else _EMPTY

    def steal(self, k: int, leave: int) -> List[_Request]:
        """Remove up to ``k`` tail requests, keeping >= ``leave`` queued."""
        with self._cond:
            k = min(k, max(0, len(self._items) - leave))
            out = [self._items.pop() for _ in range(k)]
            out.reverse()
            if out:
                self._cond.notify_all()
            return out

    def finish(self) -> None:
        with self._cond:
            self._done = True
            self._cond.notify_all()

    def close(self) -> None:
        with self._cond:
            self._done = self._dead = True
            self._items.clear()
            self._cond.notify_all()


class Replica:
    """One serving worker: a ``ServingPipeline`` driven on its own thread
    from a bounded inbox, emitting :class:`RoutedRecord`\\ s onto a shared
    output queue.

    ``engine`` may be any ``BPEngine`` -- including one whose backend is
    bound to a sub-mesh (``repro.dist.make_sharded_engine``), which is how
    replicas occupy disjoint device slices. ``rng`` must be the *router's
    shared base key*: per-request keys are ``fold_in(rng, rid)``, so a
    request's trajectory is identical on every replica -- the property the
    determinism pin and work stealing both rest on.

    The pipeline always runs with ``ingest_threads >= 1``: the inbox-
    draining source blocks waiting for dispatches, and only a feeder
    thread may block without stalling resident buckets. ``ingest_queue``
    defaults small (2) so requests stay in the *inbox* -- stealable --
    rather than pre-pulled into the feeder buffer.

    Lifecycle: ``start()`` spawns the thread; ``finish()`` marks the
    stream complete (the replica drains and exits); ``close()`` abandons
    queued work, closes the pipeline (joining its feeder threads), and
    joins the replica thread. The router calls these; replicas are not
    usually driven by hand."""

    def __init__(self, engine: BPEngine, rng: jax.Array, *, index: int = 0,
                 out: "Optional[_queue.Queue]" = None,
                 history: RoundsHistory | None = None,
                 steal_fn: "Callable[[Replica], int] | None" = None,
                 low_watermark: int = 2, inbox_capacity: int = 64,
                 growth: float = 2.0, ingest_threads: int = 1,
                 ingest_queue: int | None = 2,
                 prefetch: int | None = 8, **pipeline_kwargs):
        if prefetch is None:
            raise ValueError(
                "a replica needs a finite prefetch (prefetch=None drains "
                "the stream eagerly, which would block on the live inbox)")
        admission = pipeline_kwargs.pop("admission", None)
        admission_kwargs = dict(
            pipeline_kwargs.pop("admission_kwargs", None) or {})
        if admission is None:
            admission = getattr(engine.config, "admission", "fifo")
            if not admission_kwargs:
                admission_kwargs = dict(
                    getattr(engine.config, "admission_kwargs", ()))
        if history is not None and admission in ("residual", "deadline"):
            # Pool effort calibration tier-wide: every replica's effort-
            # aware policy reads/writes one shared (internally locked)
            # history.
            admission_kwargs.setdefault("history", history)
        self.index = index
        self.low_watermark = max(0, low_watermark)
        self._history = history
        self._steal_fn = steal_fn
        self._inbox = _Inbox(inbox_capacity)
        self._out: _queue.Queue = out if out is not None else _queue.Queue()
        self._meta: dict[int, _Request] = {}
        self.pipeline = ServingPipeline(
            engine, rng, growth=growth, prefetch=prefetch,
            ingest_threads=max(1, ingest_threads),
            ingest_queue=ingest_queue, admission=admission,
            admission_kwargs=admission_kwargs, **pipeline_kwargs)
        self.submitted = 0
        self.stolen_in = 0
        self.stolen_out = 0
        self.served = 0
        self._thread = threading.Thread(
            target=self._run, name=f"bp-replica-{index}", daemon=True)

    # -- router-facing surface --------------------------------------------

    def start(self) -> "Replica":
        """Spawn the serving thread; returns self so construction chains."""
        self._thread.start()
        return self

    def submit(self, req: _Request) -> None:
        """Enqueue one routed request (router thread; blocks while the
        inbox is at capacity -- the tier's backpressure)."""
        self._inbox.put(req)
        self.submitted += 1

    def finish(self) -> None:
        """No more submissions: drain the inbox, serve what remains (and
        keep stealing from deeper peers), then exit."""
        self._inbox.finish()

    def close(self, *, join_timeout: float = 5.0) -> None:
        """Abandon queued work and tear the replica down: close the inbox
        (the serving thread then drains out on its own -- its ``finally``
        closes the pipeline), join the serving thread, and finally
        ``pipeline.close()`` for the never-started case. Idempotent.

        Ordering matters: closing the pipeline *first* would drain the
        feeder queue -- including the exhaustion sentinel a serving thread
        blocked in ``feeder.get(block=True)`` is waiting for -- and strand
        it; closing the inbox first lets the source return and the
        shutdown flow through the normal exhaustion path."""
        self._inbox.close()
        if self._thread.is_alive():
            self._thread.join(timeout=join_timeout)
        self.pipeline.close()

    # -- load introspection ------------------------------------------------

    def _staged(self) -> int:
        # Advisory cross-thread read: the serving thread may be inserting a
        # fresh group mid-sum (dict mutation during iteration).
        for _ in range(3):
            try:
                return self.pipeline._staged_count()
            except RuntimeError:
                continue
        return 0

    def pending(self) -> int:
        """Requests queued ahead of the device: inbox + feeder buffer +
        staged (the steal trigger's watermark quantity)."""
        feeder = self.pipeline._feeder
        buffered = feeder._q.qsize() if feeder is not None else 0
        return len(self._inbox) + buffered + self._staged()

    def load(self) -> ReplicaLoad:
        """A :class:`ReplicaLoad` snapshot for routing decisions. Effort
        weights each inbox request by the shared history's expected rounds
        for its kind (``RoundsHistory.mean`` falls back kind -> global ->
        1.0 cold, so unobserved kinds assume the tier-wide average);
        staged/in-flight requests weigh the global fallback since their
        kinds are already device-committed. ``urgent`` counts deadlined
        inbox requests -- the deadline routing policy's signal."""
        snap = self._inbox.snapshot()
        fallback = 1.0 if self._history is None \
            else self._history.mean(None, default=1.0)
        est = [fallback if self._history is None
               else self._history.mean(("routed", k), default=fallback)
               for k, _ in snap]
        staged = self._staged()
        stats = self.pipeline.stats
        in_flight = max(0, int(stats.staged) - int(stats.evacuated) - staged)
        effort = sum(est) + (staged + in_flight) * fallback
        return ReplicaLoad(replica=self.index, inbox=len(snap),
                           staged=staged, in_flight=in_flight, effort=effort,
                           urgent=sum(1 for _, d in snap if d is not None))

    # -- the serving thread ------------------------------------------------

    def steal_into(self, reqs: List[_Request]) -> None:
        """Transplant stolen requests into this inbox (steal hook side;
        bypasses the capacity bound -- the work was already admitted
        tier-wide)."""
        for r in reqs:
            r.stolen = True
            self._inbox.put(r, force=True)
        self.stolen_in += len(reqs)

    def steal_from(self, k: int) -> List[_Request]:
        """Give up to ``k`` tail requests, keeping ``low_watermark``."""
        out = self._inbox.steal(k, self.low_watermark)
        self.stolen_out += len(out)
        return out

    def _source(self):
        """The pipeline's request iterator: drain the inbox, triggering a
        steal whenever pending work falls below the low watermark. Runs on
        the pipeline's ingest feeder thread, so blocking here never stalls
        resident buckets."""
        inbox = self._inbox
        while True:
            if (self._steal_fn is not None and not inbox.dead
                    and self.pending() < self.low_watermark):
                self._steal_fn(self)
            got = inbox.pop(timeout=0.05)
            if got is _CLOSED:
                if inbox.dead or self._steal_fn is None:
                    return
                # Stream finished and inbox drained -- but peers may still
                # hold stealable work. Stay alive while buckets are busy;
                # once pending drains below the watermark, a steal attempt
                # that comes back empty means no peer is above *its*
                # watermark -- and post-finish inboxes only shrink, so
                # nothing more can ever arrive: exit.
                if self.pending() >= self.low_watermark:
                    continue
                if not self._steal_fn(self) and not len(inbox):
                    return
                continue
            if got is _EMPTY:
                continue
            self._meta[got.rid] = got
            if got.deadline is None:
                yield got.rid, got.pgm, None
            else:
                # Absolute router-clock deadline back to a *remaining*
                # budget relative to the replica-local enqueue the pipeline
                # stamps (same clock tier-wide), so inbox wait counts
                # against the SLO.
                yield (got.rid, got.pgm,
                       max(got.deadline - self.pipeline.clock(), 0.0))

    def _run(self) -> None:
        err: BaseException | None = None
        try:
            for rec in self.pipeline.serve(self._source()):
                req = self._meta.pop(rec.rid)
                if self._history is not None and not rec.evicted:
                    # Evicted round counts are truncation artifacts, not
                    # effort samples -- feeding them in would teach the
                    # predictor that hard requests are cheap.
                    self._history.observe(("routed", req.kind), 0.0,
                                          float(rec.result.rounds))
                self.served += 1
                self._out.put(("rec", self.index,
                               RoutedRecord(replica=self.index,
                                            kind=req.kind, stolen=req.stolen,
                                            t_route=req.t_route, record=rec)))
        except BaseException as e:    # surfaced on the router thread
            err = e
        finally:
            self.pipeline.close()
            self._out.put(("done", self.index, err))
