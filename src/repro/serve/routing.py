"""Routing policies: *which replica takes the next request*.

The router tier (``repro.serve.router``) dispatches one heterogeneous
request stream across N :class:`~repro.serve.replica.Replica` workers; a
:class:`RoutingPolicy` makes the per-request placement call from the
replicas' live load (:class:`~repro.serve.replica.ReplicaLoad` snapshots).
Policies are addressable by string through ``ROUTING_POLICIES`` -- the
fourth ``repro.core.registry.Registry`` family, after schedulers, update
backends, and admission policies -- so ``Router(routing="least_loaded")``
stays serializable and ``register_routing_policy`` plugs in custom
strategies with the same decorator surface as the other three.

Built-ins:

- ``round_robin`` -- request i goes to replica ``i % N``, load-blind. The
  determinism anchor: with stealing off, each replica's share is a pure
  function of arrival order, so per-request results are bitwise identical
  to running that share through ``serve_async`` solo (pinned by test).
- ``least_loaded`` -- weighted shortest-queue-first: place where (pending
  depth x expected effort) is smallest. The request-granularity analog of
  Residual BP's informed-priority argument -- spend capacity where the
  backlog (in expected rounds, not just requests) is smallest.
- ``kind_affinity`` -- sticky kind -> replica placement so each replica
  sees few distinct padded shapes (bucket shapes stay hot: fewer
  compiles, denser buckets); unseen kinds seed on the least-loaded
  replica.
- ``deadline`` -- deadline-aware least-loaded: deadlined requests avoid
  replicas already holding urgent work (``ReplicaLoad.urgent``), spreading
  SLO pressure so one replica's backlog does not blow every deadline
  queued behind it. Policies whose ``pick`` accepts an ``slo`` keyword
  receive the request's latency budget; three-argument picks keep
  working untouched.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

from repro.core.registry import Registry

__all__ = ["ROUTING_POLICIES", "RoutingPolicy", "RoundRobinRouting",
           "LeastLoadedRouting", "KindAffinityRouting", "DeadlineRouting",
           "get_routing_policy", "list_routing_policies",
           "register_routing_policy"]


class RoutingPolicy:
    """Base routing policy: per-request replica placement.

    One instance drives one :class:`~repro.serve.router.Router` (policies
    hold routing state -- a round-robin cursor, an affinity map -- so
    ``bind`` refuses reuse across routers, mirroring ``AdmissionPolicy``).
    Subclasses override :meth:`pick`; the contract is a single integer in
    ``range(n_replicas)`` chosen from the request's identity and the
    replicas' load snapshots. ``pick`` runs on the router thread only, so
    policies need no internal locking.
    """

    name = "base"

    def __init__(self):
        self.router = None

    def bind(self, router) -> "RoutingPolicy":
        """Attach to the driving router (called once from its constructor);
        returns self so construction chains. Rebinding a used instance
        raises -- pass a registry spec string (always constructed fresh) or
        a new instance per router."""
        if self.router is not None and self.router is not router:
            raise ValueError(
                f"{type(self).__name__} instance is already bound to a "
                "router; routing policies are per-router -- use a registry "
                "spec string or a fresh instance")
        self.router = router
        return self

    def pick(self, rid: int, kind: Tuple[int, ...],
             loads: Sequence) -> int:
        """The replica index for request ``rid`` of bucket-shape ``kind``
        given one :class:`~repro.serve.replica.ReplicaLoad` per replica."""
        raise NotImplementedError

    @staticmethod
    def _least_loaded(loads: Sequence) -> int:
        """Smallest effort-weighted pending depth; ties break to the lowest
        index (deterministic)."""
        return min(range(len(loads)), key=lambda i: (loads[i].weight, i))


class RoundRobinRouting(RoutingPolicy):
    """Load-blind round robin: request ``rid``'s arrival position modulo
    the replica count. The determinism anchor -- each replica's share
    depends only on arrival order, never on timing -- and the right
    default for effort-homogeneous streams."""

    name = "round_robin"

    def __init__(self):
        super().__init__()
        self._next = 0

    def pick(self, rid: int, kind: Tuple[int, ...],
             loads: Sequence) -> int:
        i = self._next % len(loads)
        self._next += 1
        return i


class LeastLoadedRouting(RoutingPolicy):
    """Weighted shortest-queue placement: the replica whose pending depth,
    weighted by the shared :class:`~repro.core.batch.RoundsHistory`'s mean
    observed rounds per kind (``ReplicaLoad.weight``), is smallest. A
    replica holding two heavy requests reads as more loaded than one
    holding three light ones -- the informed-priority idea one level above
    message scheduling."""

    name = "least_loaded"

    def pick(self, rid: int, kind: Tuple[int, ...],
             loads: Sequence) -> int:
        return self._least_loaded(loads)


class KindAffinityRouting(RoutingPolicy):
    """Sticky kind -> replica placement: every request of a bucket-shape
    kind lands on the replica that saw the kind first, so each replica
    serves few distinct padded shapes -- buckets fill denser and jit
    caches stay hot (compiles scale with shapes *per replica*, not total).
    An unseen kind seeds on the currently least-loaded replica;
    ``spread`` caps how many kinds may stick to one replica before
    placement falls back to least-loaded (0 = unbounded)."""

    name = "kind_affinity"

    def __init__(self, spread: int = 0):
        super().__init__()
        if spread < 0:
            raise ValueError(f"spread must be >= 0, got {spread}")
        self.spread = spread
        self._affinity: Dict[Tuple[int, ...], int] = {}
        self._kinds_at: Dict[int, int] = {}

    def pick(self, rid: int, kind: Tuple[int, ...],
             loads: Sequence) -> int:
        i = self._affinity.get(kind)
        if i is not None and i < len(loads):
            return i
        i = self._least_loaded(loads)
        if not self.spread or self._kinds_at.get(i, 0) < self.spread:
            self._affinity[kind] = i
            self._kinds_at[i] = self._kinds_at.get(i, 0) + 1
        return i


class DeadlineRouting(RoutingPolicy):
    """Deadline-aware least-loaded placement.

    A request carrying an SLO (the router passes ``slo`` because this
    ``pick`` declares the keyword) lands on the replica minimizing
    effort-weighted depth *plus* an urgency penalty per deadlined request
    already queued there (``ReplicaLoad.urgent``), so SLO pressure spreads
    across the fleet instead of stacking behind one replica's backlog.
    Requests without a deadline place plain least-loaded -- they can
    afford to wait behind urgent work."""

    name = "deadline"

    def __init__(self, urgency_weight: float = 1.0):
        super().__init__()
        if urgency_weight < 0:
            raise ValueError(
                f"urgency_weight must be >= 0, got {urgency_weight}")
        self.urgency_weight = urgency_weight

    def pick(self, rid: int, kind: Tuple[int, ...],
             loads: Sequence, slo: "float | None" = None) -> int:
        if slo is None:
            return self._least_loaded(loads)
        return min(range(len(loads)),
                   key=lambda i: (loads[i].weight
                                  + self.urgency_weight * loads[i].urgent,
                                  i))


#: name -> RoutingPolicy class; names are the canonical serialized form
#: (``Router(routing=...)``). A ``Registry`` (dict subclass): plain-dict
#: reads keep working, unknown names raise the uniform registry KeyError.
ROUTING_POLICIES: Registry[type] = Registry("routing policy", {
    "round_robin": RoundRobinRouting,
    "least_loaded": LeastLoadedRouting,
    "kind_affinity": KindAffinityRouting,
    "deadline": DeadlineRouting,
})


def register_routing_policy(name: str, *, overwrite: bool = False):
    """Class decorator registering a :class:`RoutingPolicy` subclass under
    ``name`` (lowercased), making it addressable by string spec --
    ``Router(routing="mine")`` -- exactly like ``register_scheduler`` /
    ``register_admission_policy``. Duplicate names raise ``ValueError``
    unless ``overwrite=True``."""
    return ROUTING_POLICIES.register(name, overwrite=overwrite)


def list_routing_policies() -> List[str]:
    """Sorted registered routing-policy names (valid ``Router(routing=...)``
    specs)."""
    return ROUTING_POLICIES.names()


def get_routing_policy(spec, **kwargs) -> RoutingPolicy:
    """Resolve a routing-policy spec: a registry name (+ constructor
    kwargs) or an already-built :class:`RoutingPolicy` instance (kwargs
    must then be empty)."""
    if isinstance(spec, str):
        return ROUTING_POLICIES.lookup(spec)(**kwargs)
    if kwargs:
        raise ValueError("routing kwargs only apply to string specs, got "
                         f"instance {type(spec).__name__} plus {kwargs}")
    return spec
