"""Router: one heterogeneous request stream fanned out across N replicas.

The :class:`Router` is the serving tier above ``ServingPipeline``: it pulls
requests off a single stream, stamps each with its arrival rid and
deterministic ``bucket_shape`` kind, places it on a replica through a
pluggable :class:`~repro.serve.routing.RoutingPolicy` (the
``ROUTING_POLICIES`` registry family), and merges every replica's released
records back into one completion-order result stream with replica
attribution and tier-level latency percentiles.

Two properties are load-bearing:

- **Determinism pin.** Per-request results depend only on (rid, padded
  shape): every replica holds the same base ``rng`` (keys are
  ``fold_in(rng, rid)``) and the online path pads each request to its own
  ``bucket_shape`` ceilings, identical on every replica. With
  ``routing="round_robin"`` and ``steal=False`` each replica's share is a
  pure function of arrival order, so the router's per-request results are
  *bitwise identical* to running each share through ``serve_async`` solo
  (pinned by test); load-aware routing and stealing move requests between
  replicas without changing any result bit -- only where the sweeps run.
- **Work stealing.** A replica whose pending work drains below its low
  watermark pulls a batch from the tail of the deepest peer's inbox
  (router-arbitrated, one steal at a time). On a skewed stream this
  converts the thief's dead-slot sweeps into useful ones: same-shape
  stolen requests backfill the very slots that would otherwise idle.

This module is also where the ``jax.distributed`` multi-host rung plugs
in next: replicas already accept per-replica engines (sub-meshes), so a
process boundary replaces the thread boundary without changing the tier's
surface.
"""

from __future__ import annotations

import dataclasses
import inspect
import queue as _queue
import threading
import time
from typing import Dict, Iterable, Iterator, List, Sequence

import jax
import numpy as np

from repro.core.batch import RoundsHistory, bucket_shape
from repro.core.engine import BPConfig, BPEngine
from repro.core.serving import AsyncServeStats
from repro.serve.replica import Replica, ReplicaLoad, RoutedRecord, _Request
from repro.serve.routing import RoutingPolicy, get_routing_policy

__all__ = ["Router", "RouterResult", "RouterStats", "serve_routed"]


@dataclasses.dataclass
class RouterStats:
    """Tier-level accounting: the routing ``policy`` name, whether
    ``steal`` was enabled, per-replica ``routed`` dispatch counts, and the
    stealing totals (``steals`` events moving ``stolen`` requests)."""

    policy: str
    steal: bool
    routed: List[int]
    steals: int = 0
    stolen: int = 0

    @property
    def replicas(self) -> int:
        """Replica count behind the router."""
        return len(self.routed)


@dataclasses.dataclass
class RouterResult:
    """``serve_routed`` output: :class:`~repro.serve.replica.RoutedRecord`
    list in completion order, tier stats, and each replica's own
    ``AsyncServeStats`` (summed by the aggregate sweep properties)."""

    records: List[RoutedRecord]
    stats: RouterStats
    replica_stats: List[AsyncServeStats]

    @property
    def results(self) -> List:
        """Per-request ``BPResult`` list indexed by rid (input order for
        the usual dense 0..n-1 rids), matching ``AsyncServeResult.results``
        -- the replica fan-out is invisible here."""
        n = 1 + max((rec.rid for rec in self.records), default=-1)
        if n > 4 * len(self.records) + 64:
            raise ValueError(
                f"rids too sparse for a dense results list (max rid {n - 1} "
                f"over {len(self.records)} records); use .records instead")
        out: List = [None] * n
        for rec in self.records:
            out[rec.rid] = rec.result
        return out

    def by_replica(self) -> Dict[int, List[RoutedRecord]]:
        """Records grouped by serving replica (attribution view)."""
        out: Dict[int, List[RoutedRecord]] = {}
        for rec in self.records:
            out.setdefault(rec.replica, []).append(rec)
        return out

    def latency_percentiles(
            self, qs: Sequence[float] = (50, 90, 99), *,
            field: str = "latency",
            status: "str | None" = None) -> Dict[str, float]:
        """Tier-level latency percentiles in ms, ``{"p50": ...}``, measured
        from ``t_route`` (router queue-in) so routing and inbox wait are
        included: ``"latency"`` (route -> result), ``"admission"``
        (route -> bucket admit), or ``"service"`` (admit -> result).
        ``status`` filters to ``"completed"`` or ``"evicted"`` records
        (``None`` = all) -- deadline eviction makes raw percentiles lie
        (an evicted straggler *shrinks* them), so SLA reporting should
        pass ``status="completed"``. All-NaN when nothing matches."""
        attrs = {"latency": "latency_s", "admission": "queue_s",
                 "service": "service_s"}
        if field not in attrs:
            raise KeyError(f"field must be one of {sorted(attrs)}, "
                           f"got {field!r}")
        if status not in (None, "completed", "evicted"):
            raise ValueError("status must be None, 'completed', or "
                             f"'evicted', got {status!r}")
        recs = self.records if status is None else [
            r for r in self.records if r.status == status]
        if not recs:
            return {f"p{q:g}": float("nan") for q in qs}
        lat = np.array([getattr(r, attrs[field]) for r in recs]) * 1e3
        return {f"p{q:g}": float(np.percentile(lat, q)) for q in qs}

    @property
    def device_sweeps(self) -> int:
        """Total device sweeps across all replicas."""
        return sum(s.device_sweeps for s in self.replica_stats)

    @property
    def useful_sweeps(self) -> int:
        """Total sweeps spent on unconverged live graphs across replicas."""
        return sum(s.useful_sweeps for s in self.replica_stats)

    @property
    def wasted_sweeps(self) -> int:
        """Dead-slot / converged-graph sweeps across replicas -- the
        quantity work stealing exists to shrink."""
        return self.device_sweeps - self.useful_sweeps


class Router:
    """Multi-replica serving front-end (see module docstring).

    ``engine`` seeds the replica fleet: a ``BPConfig`` or single
    ``BPEngine`` builds ``replicas`` workers from the same config (fresh
    engines, so jit caches and threads stay per-replica), while an explicit
    engine list pins one engine per replica -- the sub-mesh hook
    (``repro.dist.make_sharded_engine`` per device slice). ``rng`` is the
    shared base key every replica folds rids into.

    ``routing`` picks the placement policy from the ``ROUTING_POLICIES``
    registry (``"round_robin"`` | ``"least_loaded"`` | ``"kind_affinity"``,
    constructed with ``routing_kwargs``) or takes a prebuilt
    :class:`~repro.serve.routing.RoutingPolicy`. ``steal=True`` enables
    watermark-triggered work stealing (``steal_batch`` requests at a time,
    victims keep ``low_watermark``). ``history`` pools effort calibration
    across replicas (one shared, internally locked
    :class:`~repro.core.batch.RoundsHistory`; default: a fresh one).
    Remaining keyword arguments flow to every
    :class:`~repro.serve.replica.Replica` and its pipeline (``slots``,
    ``max_batch``, ``admission``, ...).

    ``serve(stream)`` is a one-shot generator of
    :class:`~repro.serve.replica.RoutedRecord` in completion order; a
    router is a context manager, and :func:`serve_routed` wraps the whole
    lifecycle for collect-everything callers."""

    def __init__(self, engine, rng: jax.Array, *,
                 replicas: int | None = None,
                 routing: "str | RoutingPolicy" = "round_robin",
                 routing_kwargs=None, steal: bool = False,
                 steal_batch: int = 4, low_watermark: int = 2,
                 inbox_capacity: int = 64, growth: float = 2.0,
                 history: RoundsHistory | None = None,
                 clock=None, **replica_kwargs):
        if isinstance(engine, (list, tuple)):
            engines = list(engine)
            if not engines:
                raise ValueError("need at least one engine")
            if replicas is not None and replicas != len(engines):
                raise ValueError(
                    f"replicas={replicas} but {len(engines)} engines given")
        else:
            n = 2 if replicas is None else replicas
            if n < 1:
                raise ValueError(f"replicas must be >= 1, got {n}")
            if isinstance(engine, BPConfig):
                engines = [BPEngine(engine) for _ in range(n)]
            elif isinstance(engine, BPEngine):
                engines = [engine] + [BPEngine(engine.config)
                                      for _ in range(n - 1)]
            else:
                raise TypeError(
                    "engine must be a BPConfig, a BPEngine, or a sequence "
                    f"of BPEngines, got {type(engine).__name__}")
        if steal_batch < 1:
            raise ValueError(f"steal_batch must be >= 1, got {steal_batch}")
        self.rng = rng
        self.growth = growth
        self.steal = steal
        self.steal_batch = steal_batch
        self._policy = get_routing_policy(
            routing, **dict(routing_kwargs or {})).bind(self)
        # Deadline-aware policies take an extra slo kwarg; inspect once so
        # the tier keeps working with legacy 3-arg pick signatures.
        params = inspect.signature(self._policy.pick).parameters
        self._pick_slo = "slo" in params or any(
            p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values())
        self.clock = clock if clock is not None else time.perf_counter
        if clock is not None:
            # One time source tier-wide: replica pipelines stamp
            # enqueue/admit/done on the router's clock, so absolute
            # deadlines compare across the thread boundary.
            replica_kwargs.setdefault("clock", clock)
        self._history = history if history is not None else RoundsHistory()
        self._out: _queue.Queue = _queue.Queue()
        self._steal_lock = threading.Lock()
        self.stats = RouterStats(policy=self._policy.name, steal=steal,
                                 routed=[0] * len(engines))
        self.replicas = [
            Replica(eng, rng, index=i, out=self._out, history=self._history,
                    steal_fn=self._steal_for if steal else None,
                    low_watermark=low_watermark,
                    inbox_capacity=inbox_capacity, growth=growth,
                    **replica_kwargs)
            for i, eng in enumerate(engines)]
        self._arrival = 0
        self._live = 0
        self._explicit_rids = False
        self._seen_rids: set[int] = set()
        self._started = False
        self._closed = False

    # -- work stealing -----------------------------------------------------

    def _steal_for(self, thief: Replica) -> int:
        """Steal hook, called from a starving replica's source thread:
        transplant up to ``steal_batch`` requests from the tail of the
        deepest peer's inbox (victims keep their low watermark). The lock
        serializes concurrent thieves so two never split one victim's
        tail."""
        with self._steal_lock:
            victims = [r for r in self.replicas if r is not thief]
            victim = max(victims, key=lambda r: len(r._inbox), default=None)
            if victim is None or len(victim._inbox) <= victim.low_watermark:
                return 0
            reqs = victim.steal_from(self.steal_batch)
            if not reqs:
                return 0
            thief.steal_into(reqs)
            self.stats.steals += 1
            self.stats.stolen += len(reqs)
            return len(reqs)

    # -- loads -------------------------------------------------------------

    def loads(self) -> List[ReplicaLoad]:
        """One :class:`~repro.serve.replica.ReplicaLoad` snapshot per
        replica (what routing policies see)."""
        return [r.load() for r in self.replicas]

    # -- the dispatch loop -------------------------------------------------

    def serve(self, stream: Iterable) -> Iterator[RoutedRecord]:
        """Dispatch ``stream`` across the replicas, yielding one
        :class:`~repro.serve.replica.RoutedRecord` per request in
        completion order. One-shot: a Router serves one stream. The stream
        may yield ``PGM``\\ s (rid = arrival order), explicit
        ``(rid, PGM)`` pairs, or ``(rid, PGM, slo_s)`` deadline triples
        (``rid=None`` keeps arrival-order rids), exactly like
        ``serve_async``; replica results interleave as they complete.
        An SLO is seconds from *router* queue-in: the absolute deadline
        travels with the request (across steals too), and the replica
        charges routing + inbox wait against the budget."""
        if self._started:
            raise ValueError("Router.serve is one-shot; build a fresh "
                             "Router per stream")
        if self._closed:
            raise ValueError("Router is closed")
        self._started = True
        for r in self.replicas:
            r.start()
        self._live = len(self.replicas)
        try:
            for item in iter(stream):
                t = self.clock()
                slo = None
                if isinstance(item, tuple):
                    if len(item) == 3:
                        rid, pgm, slo = item
                        slo = None if slo is None else float(slo)
                    else:
                        rid, pgm = item
                    if rid is None:
                        rid = self._arrival
                    else:
                        rid = int(rid)
                        self._explicit_rids = True
                else:
                    rid, pgm = self._arrival, item
                if self._explicit_rids:
                    if rid in self._seen_rids:
                        raise ValueError(
                            f"duplicate request id {rid} in stream")
                    self._seen_rids.add(rid)
                self._arrival += 1
                kind = bucket_shape(pgm, self.growth)
                if self._pick_slo:
                    i = self._policy.pick(rid, kind, self.loads(), slo=slo)
                else:
                    i = self._policy.pick(rid, kind, self.loads())
                if not 0 <= i < len(self.replicas):
                    raise ValueError(
                        f"routing policy picked replica {i}, have "
                        f"{len(self.replicas)}")
                self.stats.routed[i] += 1
                deadline = None if slo is None else t + slo
                self.replicas[i].submit(
                    _Request(rid, pgm, kind, t, deadline=deadline))
                yield from self._drain(block=False)
            for r in self.replicas:
                r.finish()
            while self._live:
                yield from self._drain(block=True)
        finally:
            self.close()

    def _drain(self, block: bool) -> Iterator[RoutedRecord]:
        """Pull completed records off the shared output queue: everything
        currently available, waiting for at most one item when ``block``.
        Replica errors re-raise here, on the router thread."""
        while True:
            try:
                tag, idx, payload = self._out.get(
                    block=block, timeout=0.2 if block else None)
            except _queue.Empty:
                return
            block = False
            if tag == "done":
                self._live -= 1
                if payload is not None:
                    raise payload
            else:
                yield payload

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        """Tear the tier down: close every replica (inbox, serving thread,
        pipeline + feeder threads all joined). Idempotent; also runs from
        ``serve``'s ``finally``, so an abandoned generator cannot leak
        replica threads."""
        if self._closed:
            return
        self._closed = True
        for r in self.replicas:
            r.close()

    def __enter__(self) -> "Router":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        """Context-manager exit: ``close()`` -- all replica threads
        joined."""
        self.close()


def serve_routed(engine, stream, rng: jax.Array, *,
                 replicas: int | None = None,
                 routing: "str | RoutingPolicy" = "round_robin",
                 steal: bool = False, **kwargs) -> RouterResult:
    """Serve a request stream through a replica fleet and collect
    everything: builds a :class:`Router` (``engine`` is a ``BPConfig``,
    ``BPEngine``, or per-replica engine list; remaining keyword arguments
    flow through), drains ``Router.serve`` to completion, and returns a
    :class:`RouterResult` -- records in completion order, ``.results`` in
    rid order, tier stats plus per-replica pipeline stats. The
    multi-replica analog of :func:`~repro.core.serving.serve_async`."""
    with Router(engine, rng, replicas=replicas, routing=routing,
                steal=steal, **kwargs) as router:
        records = list(router.serve(stream))
        return RouterResult(
            records=records, stats=router.stats,
            replica_stats=[r.pipeline.stats for r in router.replicas])
