"""Benchmark PGM generators (paper SS III-C, SS IV-C, SS IV-E).

Ising grids: N x N binary variables. Unary psi_i ~ U[0,1] (per-state sample).
Pairwise: psi_ij = e^{lambda C} if x_i == x_j else e^{-lambda C}, with
lambda ~ U[-0.5, 0.5] per edge; C controls difficulty (paper uses C in
{2, 2.5, 3}).

Chains: N binary variables in a path; same potential sampling, C = 10 in the
paper. BP is exact and guaranteed-convergent on chains -- the paper uses them
to expose scheduler *overhead* (LBP wins on chains; sort-and-select loses).

Protein-like graphs (SS IV-E): the paper uses Yanover & Weiss's side-chain
prediction MRFs -- irregular structure, 2..81 states per vertex. The dataset
is not redistributable, so we generate structurally matched stand-ins:
random geometric graphs (spatially local contacts, like residue contact
maps) with per-vertex state counts drawn from 2..81 and dense positive
pairwise tables with a controllable coupling strength.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.graph import PGM, build_pgm, build_pgm_uniform


def _grid_edges(n: int) -> np.ndarray:
    """Vectorized N x N grid edge list."""
    idx = np.arange(n * n).reshape(n, n)
    horiz = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], axis=1)
    vert = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], axis=1)
    return np.concatenate([horiz, vert], axis=0)


def ising_grid_fast(n: int, C: float, seed: int = 0, *,
                    dtype=None) -> PGM:
    """Vectorized Ising grid builder (identical distribution family to
    ``ising_grid``; use for large dry-run graphs where the per-edge python
    loop is prohibitive)."""
    rng = np.random.default_rng(seed)
    edges = _grid_edges(n)
    unary = rng.uniform(1e-3, 1.0, size=(n * n, 2))
    lam = rng.uniform(-0.5, 0.5, size=len(edges))
    agree, disagree = np.exp(lam * C), np.exp(-lam * C)
    pairwise = np.empty((len(edges), 2, 2))
    pairwise[:, 0, 0] = pairwise[:, 1, 1] = agree
    pairwise[:, 0, 1] = pairwise[:, 1, 0] = disagree
    kwargs = {} if dtype is None else dict(dtype=dtype)
    return build_pgm_uniform(n * n, edges, unary, pairwise, **kwargs)


def _ising_potentials(rng: np.random.Generator, n_edges: int, C: float
                      ) -> List[np.ndarray]:
    lam = rng.uniform(-0.5, 0.5, size=n_edges)
    agree = np.exp(lam * C)
    disagree = np.exp(-lam * C)
    return [np.array([[a, d], [d, a]]) for a, d in zip(agree, disagree)]


def ising_grid(n: int, C: float, seed: int = 0, *, dtype=None) -> PGM:
    """N x N Ising grid, paper SS III-C."""
    rng = np.random.default_rng(seed)
    v = lambda r, c: r * n + c
    edges = []
    for r in range(n):
        for c in range(n):
            if c + 1 < n:
                edges.append((v(r, c), v(r, c + 1)))
            if r + 1 < n:
                edges.append((v(r, c), v(r + 1, c)))
    edges = np.array(edges, dtype=np.int64)
    # "Univariate potentials are randomly sampled from the [0,1] range."
    unary = [rng.uniform(1e-3, 1.0, size=2) for _ in range(n * n)]
    pairwise = _ising_potentials(rng, len(edges), C)
    kwargs = {} if dtype is None else dict(dtype=dtype)
    return build_pgm(n * n, edges, unary, pairwise, **kwargs)


def small_ising(n: int = 10, C: float = 2.0, seed: int = 0
                ) -> Tuple[PGM, int, np.ndarray, list, list]:
    """Ising grid plus raw (edges, unary, pairwise) for the exact oracle
    (paper Fig 5 uses 10x10, C=2)."""
    rng = np.random.default_rng(seed)
    v = lambda r, c: r * n + c
    edges = []
    for r in range(n):
        for c in range(n):
            if c + 1 < n:
                edges.append((v(r, c), v(r, c + 1)))
            if r + 1 < n:
                edges.append((v(r, c), v(r + 1, c)))
    edges = np.array(edges, dtype=np.int64)
    unary = [rng.uniform(1e-3, 1.0, size=2) for _ in range(n * n)]
    pairwise = _ising_potentials(rng, len(edges), C)
    return build_pgm(n * n, edges, unary, pairwise), n * n, edges, unary, pairwise


def chain_graph(n: int, C: float = 10.0, seed: int = 0) -> PGM:
    """Length-n binary chain, paper SS III-C (n = 100000, C = 10)."""
    rng = np.random.default_rng(seed)
    edges = np.stack([np.arange(n - 1), np.arange(1, n)], axis=1)
    unary = [rng.uniform(1e-3, 1.0, size=2) for _ in range(n)]
    pairwise = _ising_potentials(rng, len(edges), C)
    return build_pgm(n, edges, unary, pairwise)


def loop_graph(n: int, C: float = 2.0, seed: int = 0) -> PGM:
    """Length-n binary cycle (single loop). The minimal loopy graph: BP is
    no longer exact but converges fast -- a cheap mixed-batch member that
    stresses the batched engine with a third structure class."""
    rng = np.random.default_rng(seed)
    edges = np.stack([np.arange(n), (np.arange(n) + 1) % n], axis=1)
    unary = [rng.uniform(1e-3, 1.0, size=2) for _ in range(n)]
    pairwise = _ising_potentials(rng, len(edges), C)
    return build_pgm(n, edges, unary, pairwise)


def protein_like_graph(n_vertices: int = 120, seed: int = 0, *,
                       max_states: int = 81, coupling: float = 2.0,
                       radius: float = 0.14) -> PGM:
    """Irregular mixed-cardinality MRF shaped like side-chain prediction
    problems (paper SS IV-E): spatial contact graph, 2..max_states states.

    Pairwise tables are exp(coupling * U(-1, 1)) -- bounded log-dynamic
    range, like Boltzmann-energy potentials. (A heavy-tailed exp(c*N(0,1))
    variant makes BP non-convergent for EVERY scheduler at these sizes and
    does not reproduce the paper's SSIV-E phenomenology: at these defaults
    LBP converges on ~half the instances while RnBP(0.4, 0.9) converges on
    all of them, faster -- exactly Fig 4f.)"""
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0.0, 1.0, size=(n_vertices, 2))
    edges = []
    for i in range(n_vertices):
        for j in range(i + 1, n_vertices):
            if np.linalg.norm(pos[i] - pos[j]) < radius:
                edges.append((i, j))
    # ensure connectivity along a backbone (residue chain)
    for i in range(n_vertices - 1):
        if (i, i + 1) not in edges:
            edges.append((i, i + 1))
    edges = np.array(sorted(set(map(tuple, edges))), dtype=np.int64)
    # state counts: skewed toward small, ranging 2..max_states (paper: 2..81)
    n_states = np.clip(
        rng.geometric(p=0.08, size=n_vertices) + 1, 2, max_states)
    unary = [rng.uniform(1e-2, 1.0, size=int(s)) for s in n_states]
    pairwise = []
    for (i, j) in edges:
        si, sj = int(n_states[i]), int(n_states[j])
        table = np.exp(coupling * rng.uniform(-1.0, 1.0, (si, sj)))
        pairwise.append(table)
    return build_pgm(n_vertices, edges, unary, pairwise)
