"""Benchmark PGM generators (paper SS III-C, SS IV-C, SS IV-E).

Ising grids: N x N binary variables. Unary psi_i ~ U[0,1] (per-state sample).
Pairwise: psi_ij = e^{lambda C} if x_i == x_j else e^{-lambda C}, with
lambda ~ U[-0.5, 0.5] per edge; C controls difficulty (paper uses C in
{2, 2.5, 3}).

Chains: N binary variables in a path; same potential sampling, C = 10 in the
paper. BP is exact and guaranteed-convergent on chains -- the paper uses them
to expose scheduler *overhead* (LBP wins on chains; sort-and-select loses).

Protein-like graphs (SS IV-E): the paper uses Yanover & Weiss's side-chain
prediction MRFs -- irregular structure, 2..81 states per vertex. The dataset
is not redistributable, so we generate structurally matched stand-ins:
random geometric graphs (spatially local contacts, like residue contact
maps) with per-vertex state counts drawn from 2..81 and dense positive
pairwise tables with a controllable coupling strength.

LDPC decoding (the paper's error-correcting-codes motivation): a regular
Gallager parity-check code becomes a pairwise MRF by giving every check an
auxiliary vertex whose states enumerate the even-parity assignments of its
member bits; BPSK-over-AWGN channel LLRs are the bit unaries and the
existing max-product path decodes MAP codewords (``ldpc_code`` /
``ldpc_graph``).

Stereo-vision MRF (the paper's vision motivation): a rectangular grid over
a synthetic disparity scene with truncated-linear data and smoothness
terms -- the classic stereo energy, and at image scale the natural stress
test for the banded dist path (``stereo_mrf`` / ``stereo_graph``).

The ``WORKLOADS`` registry names every zoo member
(``register_workload`` / ``list_workloads`` / ``get_workload``) and
``zoo_stream`` interleaves them at mixed kinds *and* sizes -- the
heterogeneous request stream the serving tier's admission and routing
policies were built for.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator, List, Mapping, Sequence, Tuple

import numpy as np

from repro.core.graph import PGM, build_pgm, build_pgm_uniform
from repro.core.registry import Registry

__all__ = [
    "LDPCInstance", "StereoInstance", "WORKLOADS", "chain_graph",
    "get_workload", "ising_grid", "ising_grid_fast", "ldpc_code",
    "ldpc_graph", "list_workloads", "loop_graph", "protein_like_graph",
    "register_workload", "small_ising", "stereo_graph", "stereo_mrf",
    "zoo_stream",
]


def _grid_edges(n: int) -> np.ndarray:
    """Vectorized N x N grid edge list."""
    idx = np.arange(n * n).reshape(n, n)
    horiz = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], axis=1)
    vert = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], axis=1)
    return np.concatenate([horiz, vert], axis=0)


def ising_grid_fast(n: int, C: float, seed: int = 0, *,
                    dtype=None) -> PGM:
    """Vectorized Ising grid builder (identical distribution family to
    ``ising_grid``; use for large dry-run graphs where the per-edge python
    loop is prohibitive)."""
    rng = np.random.default_rng(seed)
    edges = _grid_edges(n)
    unary = rng.uniform(1e-3, 1.0, size=(n * n, 2))
    lam = rng.uniform(-0.5, 0.5, size=len(edges))
    agree, disagree = np.exp(lam * C), np.exp(-lam * C)
    pairwise = np.empty((len(edges), 2, 2))
    pairwise[:, 0, 0] = pairwise[:, 1, 1] = agree
    pairwise[:, 0, 1] = pairwise[:, 1, 0] = disagree
    kwargs = {} if dtype is None else dict(dtype=dtype)
    return build_pgm_uniform(n * n, edges, unary, pairwise, **kwargs)


def _ising_potentials(rng: np.random.Generator, n_edges: int, C: float
                      ) -> List[np.ndarray]:
    lam = rng.uniform(-0.5, 0.5, size=n_edges)
    agree = np.exp(lam * C)
    disagree = np.exp(-lam * C)
    return [np.array([[a, d], [d, a]]) for a, d in zip(agree, disagree)]


def ising_grid(n: int, C: float, seed: int = 0, *, dtype=None) -> PGM:
    """N x N Ising grid, paper SS III-C: uniform [0,1] unaries and
    agree/disagree pairwise tables at coupling strength ``C``."""
    rng = np.random.default_rng(seed)
    v = lambda r, c: r * n + c
    edges = []
    for r in range(n):
        for c in range(n):
            if c + 1 < n:
                edges.append((v(r, c), v(r, c + 1)))
            if r + 1 < n:
                edges.append((v(r, c), v(r + 1, c)))
    edges = np.array(edges, dtype=np.int64)
    # "Univariate potentials are randomly sampled from the [0,1] range."
    unary = [rng.uniform(1e-3, 1.0, size=2) for _ in range(n * n)]
    pairwise = _ising_potentials(rng, len(edges), C)
    kwargs = {} if dtype is None else dict(dtype=dtype)
    return build_pgm(n * n, edges, unary, pairwise, **kwargs)


def small_ising(n: int = 10, C: float = 2.0, seed: int = 0
                ) -> Tuple[PGM, int, np.ndarray, list, list]:
    """Ising grid plus raw (edges, unary, pairwise) for the exact oracle
    (paper Fig 5 uses 10x10, C=2)."""
    rng = np.random.default_rng(seed)
    v = lambda r, c: r * n + c
    edges = []
    for r in range(n):
        for c in range(n):
            if c + 1 < n:
                edges.append((v(r, c), v(r, c + 1)))
            if r + 1 < n:
                edges.append((v(r, c), v(r + 1, c)))
    edges = np.array(edges, dtype=np.int64)
    unary = [rng.uniform(1e-3, 1.0, size=2) for _ in range(n * n)]
    pairwise = _ising_potentials(rng, len(edges), C)
    return build_pgm(n * n, edges, unary, pairwise), n * n, edges, unary, pairwise


def chain_graph(n: int, C: float = 10.0, seed: int = 0) -> PGM:
    """Length-n binary chain, paper SS III-C (n = 100000, C = 10)."""
    rng = np.random.default_rng(seed)
    edges = np.stack([np.arange(n - 1), np.arange(1, n)], axis=1)
    unary = [rng.uniform(1e-3, 1.0, size=2) for _ in range(n)]
    pairwise = _ising_potentials(rng, len(edges), C)
    return build_pgm(n, edges, unary, pairwise)


def loop_graph(n: int, C: float = 2.0, seed: int = 0) -> PGM:
    """Length-n binary cycle (single loop). The minimal loopy graph: BP is
    no longer exact but converges fast -- a cheap mixed-batch member that
    stresses the batched engine with a third structure class."""
    rng = np.random.default_rng(seed)
    edges = np.stack([np.arange(n), (np.arange(n) + 1) % n], axis=1)
    unary = [rng.uniform(1e-3, 1.0, size=2) for _ in range(n)]
    pairwise = _ising_potentials(rng, len(edges), C)
    return build_pgm(n, edges, unary, pairwise)


def protein_like_graph(n_vertices: int = 120, seed: int = 0, *,
                       max_states: int = 81, coupling: float = 2.0,
                       radius: float = 0.14) -> PGM:
    """Irregular mixed-cardinality MRF shaped like side-chain prediction
    problems (paper SS IV-E): spatial contact graph, 2..max_states states.

    Pairwise tables are exp(coupling * U(-1, 1)) -- bounded log-dynamic
    range, like Boltzmann-energy potentials. (A heavy-tailed exp(c*N(0,1))
    variant makes BP non-convergent for EVERY scheduler at these sizes and
    does not reproduce the paper's SSIV-E phenomenology: at these defaults
    LBP converges on ~half the instances while RnBP(0.4, 0.9) converges on
    all of them, faster -- exactly Fig 4f.)"""
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0.0, 1.0, size=(n_vertices, 2))
    edges = []
    for i in range(n_vertices):
        for j in range(i + 1, n_vertices):
            if np.linalg.norm(pos[i] - pos[j]) < radius:
                edges.append((i, j))
    # ensure connectivity along a backbone (residue chain)
    for i in range(n_vertices - 1):
        if (i, i + 1) not in edges:
            edges.append((i, i + 1))
    edges = np.array(sorted(set(map(tuple, edges))), dtype=np.int64)
    # state counts: skewed toward small, ranging 2..max_states (paper: 2..81)
    n_states = np.clip(
        rng.geometric(p=0.08, size=n_vertices) + 1, 2, max_states)
    unary = [rng.uniform(1e-2, 1.0, size=int(s)) for s in n_states]
    pairwise = []
    for (i, j) in edges:
        si, sj = int(n_states[i]), int(n_states[j])
        table = np.exp(coupling * rng.uniform(-1.0, 1.0, (si, sj)))
        pairwise.append(table)
    return build_pgm(n_vertices, edges, unary, pairwise)


# ------------------------------------------------------------------ LDPC --

def _gallager_checks(rng: np.random.Generator, n: int, dv: int, dc: int
                     ) -> List[Tuple[int, ...]]:
    """Regular Gallager construction: the n*dv bit sockets are permuted into
    m = n*dv/dc checks of dc sockets each; duplicate memberships within a
    check are repaired by deterministic socket swaps (seeded ``rng``), so
    every check touches dc *distinct* bits."""
    assert (n * dv) % dc == 0, f"n*dv={n * dv} must divide by dc={dc}"
    m = n * dv // dc
    checks = rng.permutation(np.repeat(np.arange(n), dv)).reshape(m, dc)
    for _ in range(100 * n * dv):
        dup = None
        for c in range(m):
            vals, cnt = np.unique(checks[c], return_counts=True)
            if np.any(cnt > 1):
                dup = (c, int(vals[cnt > 1][0]))
                break
        if dup is None:
            return [tuple(sorted(int(b) for b in row)) for row in checks]
        c, v = dup
        k = int(np.where(checks[c] == v)[0][0])
        c2, k2 = int(rng.integers(m)), int(rng.integers(dc))
        checks[c, k], checks[c2, k2] = checks[c2, k2], checks[c, k]
    raise ValueError(
        f"could not repair duplicate sockets for (n={n}, dv={dv}, dc={dc})")


@dataclasses.dataclass(frozen=True)
class LDPCInstance:
    """One simulated LDPC transmission: the decoder PGM plus everything the
    exact oracles and BER accounting need.

    The all-zero codeword is BPSK-modulated (bit 0 -> +1) over an AWGN
    channel at ``snr_db``; ``y`` are the received samples, ``llr`` the
    channel log-likelihood ratios. Bits are the first ``n_bits`` vertices
    (2 states); each parity check is an auxiliary vertex whose states
    enumerate its even-parity member assignments, tied to each member bit
    by a smoothed indicator table. Decode with the max-product backend and
    read bit ``i`` from ``map_assignment(...)[:n_bits]``."""

    pgm: PGM
    n_bits: int
    checks: Tuple[Tuple[int, ...], ...]
    y: np.ndarray                       # (n_bits,) received samples
    llr: np.ndarray                     # (n_bits,) channel LLRs (clipped)
    sigma: float
    snr_db: float
    edges: np.ndarray                   # (E, 2) bit -> check-aux
    unary: Tuple[np.ndarray, ...]
    pairwise: Tuple[np.ndarray, ...]

    @property
    def n_vertices(self) -> int:
        """Total vertex count: ``n_bits`` bits + one auxiliary per check."""
        return self.n_bits + len(self.checks)

    def raw(self):
        """``(n_vertices, edges, unary, pairwise)`` for the exact oracles
        (``brute_force_marginals`` / ``ve_marginals``)."""
        return (self.n_vertices, [tuple(e) for e in self.edges],
                list(self.unary), list(self.pairwise))

    @property
    def uncoded_errors(self) -> int:
        """Hard-decision bit errors on the raw channel samples -- the
        uncoded baseline a decoder must beat."""
        return int(np.sum(self.y < 0))

    def coded_errors(self, decoded_bits: np.ndarray) -> int:
        """Bit errors of a decoded assignment vs the all-zero codeword."""
        return int(np.sum(np.asarray(decoded_bits)[: self.n_bits] != 0))


def ldpc_code(n: int = 48, *, dv: int = 3, dc: int = 6, snr_db: float = 2.0,
              seed: int = 0, check_eps: float = 1e-6,
              llr_clip: float = 25.0) -> LDPCInstance:
    """Simulate one (n, dv, dc)-regular LDPC transmission as a decoder PGM.

    The all-zero codeword (valid for every parity-check code) is sent as
    BPSK +1 over AWGN with ``sigma**2 = 1 / (2 * 10**(snr_db/10))``; bit
    unaries are ``exp(+-llr/2)`` with exponents clipped to ``llr_clip``.
    Each check's auxiliary vertex has ``2**(dc-1)`` even-parity states; the
    table tying it to its k-th member bit is 1.0 where the state agrees
    with the bit and ``check_eps`` elsewhere (``build_pgm`` requires
    strictly positive potentials, so the indicator is smoothed)."""
    rng = np.random.default_rng(seed)
    checks = _gallager_checks(rng, n, dv, dc)
    m = len(checks)
    snr = 10.0 ** (snr_db / 10.0)
    sigma = float(np.sqrt(1.0 / (2.0 * snr)))
    y = 1.0 + sigma * rng.normal(size=n)
    llr = np.clip(2.0 * y / sigma ** 2, -2.0 * llr_clip, 2.0 * llr_clip)
    unary = [np.exp(np.clip(np.array([l / 2.0, -l / 2.0]), -llr_clip,
                            llr_clip)) for l in llr]
    configs = np.array([c for c in itertools.product((0, 1), repeat=dc)
                        if sum(c) % 2 == 0])                # (2**(dc-1), dc)
    n_cfg = len(configs)
    unary += [np.ones(n_cfg) for _ in range(m)]
    edges, pairwise = [], []
    for c, members in enumerate(checks):
        for k, b in enumerate(members):
            edges.append((b, n + c))
            table = np.full((2, n_cfg), check_eps)
            table[configs[:, k], np.arange(n_cfg)] = 1.0
            pairwise.append(table)
    edges = np.array(edges, dtype=np.int64)
    pgm = build_pgm(n + m, edges, unary, pairwise)
    return LDPCInstance(pgm=pgm, n_bits=n, checks=tuple(checks),
                        y=y, llr=llr, sigma=sigma, snr_db=snr_db,
                        edges=edges, unary=tuple(unary),
                        pairwise=tuple(pairwise))


def ldpc_graph(seed: int = 0, *, n: int = 48, dv: int = 3, dc: int = 6,
               snr_db: float = 2.0, **kwargs) -> PGM:
    """PGM-only view of :func:`ldpc_code` -- the zoo/serving entry point
    (one fresh noise realization and code per ``seed``)."""
    return ldpc_code(n, dv=dv, dc=dc, snr_db=snr_db, seed=seed,
                     **kwargs).pgm


# ---------------------------------------------------------------- stereo --

def _grid_edges_rect(height: int, width: int) -> np.ndarray:
    """Vectorized height x width grid edge list (4-neighborhood)."""
    idx = np.arange(height * width).reshape(height, width)
    horiz = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], axis=1)
    vert = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], axis=1)
    return np.concatenate([horiz, vert], axis=0)


@dataclasses.dataclass(frozen=True)
class StereoInstance:
    """One synthetic stereo-matching MRF: the grid PGM plus the scene.

    ``truth`` is the ground-truth disparity map (a slanted background plane
    with a raised foreground rectangle), ``obs`` the noisy per-pixel
    disparity observation (Gaussian noise plus uniform outliers). Vertices
    are pixels in row-major order with ``n_disp`` states; decode with
    max-product and score via :meth:`accuracy` / :meth:`energy`."""

    pgm: PGM
    height: int
    width: int
    n_disp: int
    truth: np.ndarray                   # (H, W) int ground-truth disparity
    obs: np.ndarray                     # (H, W) float noisy observation
    edges: np.ndarray                   # (E, 2) grid edges
    unary: np.ndarray                   # (H*W, n_disp)
    pairwise: np.ndarray                # (E, n_disp, n_disp)

    def raw(self):
        """``(n_vertices, edges, unary, pairwise)`` for the exact oracles."""
        n = self.height * self.width
        return (n, [tuple(e) for e in self.edges],
                [self.unary[i] for i in range(n)],
                [self.pairwise[k] for k in range(len(self.edges))])

    def energy(self, labels: np.ndarray) -> float:
        """Negative log-potential of a disparity labeling (lower is better);
        the MAP objective max-product minimizes."""
        lbl = np.asarray(labels).reshape(-1)[: self.height * self.width]
        e = -float(np.sum(np.log(self.unary[np.arange(lbl.size), lbl])))
        e -= float(np.sum(np.log(
            self.pairwise[np.arange(len(self.edges)),
                          lbl[self.edges[:, 0]], lbl[self.edges[:, 1]]])))
        return e

    def accuracy(self, labels: np.ndarray, slack: int = 1) -> float:
        """Fraction of pixels whose decoded disparity is within ``slack``
        of ground truth (the standard stereo bad-pixel metric's complement)."""
        lbl = np.asarray(labels).reshape(-1)[: self.height * self.width]
        return float(np.mean(
            np.abs(lbl - self.truth.reshape(-1)) <= slack))


def stereo_mrf(height: int = 12, width: int = 16, n_disp: int = 8, *,
               seed: int = 0, noise: float = 0.6, outlier_frac: float = 0.05,
               lam_data: float = 1.0, trunc_data: float = 2.0,
               lam_smooth: float = 0.55,
               trunc_smooth: float = 2.0) -> StereoInstance:
    """Synthetic stereo-vision MRF: truncated-linear data + smoothness.

    The scene is a disparity ramp (a slanted background plane) with a
    raised foreground rectangle; observations add Gaussian noise and a
    fraction of uniform outliers. Potentials are the classic stereo energy:
    ``exp(-lam_data * min(|d - obs|, trunc_data))`` unaries and
    ``exp(-lam_smooth * min(|d_i - d_j|, trunc_smooth))`` pairwise terms
    (truncated-linear smoothness preserves disparity edges). Row-major
    pixel order keeps the grid's band structure contiguous -- at image
    scale this is the banded dist path's stress test."""
    rng = np.random.default_rng(seed)
    _, cc = np.meshgrid(np.arange(height), np.arange(width), indexing="ij")
    truth = np.clip(np.round((cc / max(width - 1, 1)) * (n_disp // 2)),
                    0, n_disp - 1).astype(int)
    fh, fw = max(1, height // 3), max(1, width // 3)
    r0, c0 = height // 4, width // 4
    truth[r0:r0 + fh, c0:c0 + fw] = max(n_disp - 2, 0)
    obs = truth + rng.normal(0.0, noise, truth.shape)
    outliers = rng.random(truth.shape) < outlier_frac
    obs[outliers] = rng.integers(0, n_disp, int(outliers.sum()))
    d = np.arange(n_disp)
    unary = np.exp(-lam_data * np.minimum(
        np.abs(obs.reshape(-1, 1) - d), trunc_data))
    edges = _grid_edges_rect(height, width)
    smooth = np.exp(-lam_smooth * np.minimum(
        np.abs(d[:, None] - d[None, :]), trunc_smooth))
    pairwise = np.broadcast_to(
        smooth, (len(edges), n_disp, n_disp)).copy()
    pgm = build_pgm_uniform(height * width, edges, unary, pairwise)
    return StereoInstance(pgm=pgm, height=height, width=width, n_disp=n_disp,
                          truth=truth, obs=obs, edges=edges, unary=unary,
                          pairwise=pairwise)


def stereo_graph(seed: int = 0, *, height: int = 12, width: int = 16,
                 n_disp: int = 8, **kwargs) -> PGM:
    """PGM-only view of :func:`stereo_mrf` -- the zoo/serving entry point
    (one fresh scene realization per ``seed``)."""
    return stereo_mrf(height, width, n_disp, seed=seed, **kwargs).pgm


# ----------------------------------------------------- workload registry --

#: name -> ``fn(seed=0, **size_kwargs) -> PGM`` zoo generator. A
#: ``Registry`` (dict subclass), the same family pattern as schedulers /
#: update backends / admission / routing, so CLI ``choices=`` and streaming
#: drivers enumerate exactly what is registered.
WORKLOADS: Registry = Registry("workload", {})


def register_workload(name: str, *, overwrite: bool = False):
    """Decorator registering a zoo generator under ``name`` (lowercased).
    Generators take ``seed`` plus size kwargs and return a ``PGM``;
    duplicates raise ``ValueError`` unless ``overwrite=True``."""
    return WORKLOADS.register(name, overwrite=overwrite)


def list_workloads() -> List[str]:
    """Sorted registered workload names (valid ``get_workload`` /
    ``bp_serving.py --workload`` specs)."""
    return WORKLOADS.names()


def get_workload(name: str):
    """Resolve a workload name to its registered generator function."""
    return WORKLOADS.lookup(name)


@register_workload("ising")
def _ising_workload(seed: int = 0, *, n: int = 10, C: float = 2.0) -> PGM:
    """N x N Ising grid zoo member (paper SS III-C potentials)."""
    return ising_grid(n, C, seed=seed)


@register_workload("chain")
def _chain_workload(seed: int = 0, *, n: int = 300, C: float = 10.0) -> PGM:
    """Binary-chain zoo member: BP-exact, exposes scheduler overhead."""
    return chain_graph(n, C, seed=seed)


@register_workload("protein")
def _protein_workload(seed: int = 0, *, n_vertices: int = 40) -> PGM:
    """Protein-like mixed-cardinality zoo member (2..81 states)."""
    return protein_like_graph(n_vertices, seed=seed)


@register_workload("ldpc")
def _ldpc_workload(seed: int = 0, *, n: int = 48, dv: int = 3, dc: int = 6,
                   snr_db: float = 2.0) -> PGM:
    """LDPC decoding zoo member: one fresh AWGN transmission per seed."""
    return ldpc_graph(seed, n=n, dv=dv, dc=dc, snr_db=snr_db)


@register_workload("stereo")
def _stereo_workload(seed: int = 0, *, height: int = 12, width: int = 16,
                     n_disp: int = 8) -> PGM:
    """Stereo-vision grid-MRF zoo member: one fresh scene per seed."""
    return stereo_graph(seed, height=height, width=width, n_disp=n_disp)


#: ``zoo_stream``'s interleave table: (kind, size kwargs) per slot. Two
#: size variants per kind, so a stream mixes shapes *within* each kind too
#: -- the bucketing/admission stressor.
_ZOO_VARIANTS: Tuple[Tuple[str, dict], ...] = (
    ("ising", dict(n=6, C=2.0)),
    ("chain", dict(n=120)),
    ("ldpc", dict(n=24, dv=2, dc=4)),
    ("stereo", dict(height=6, width=8, n_disp=4)),
    ("protein", dict(n_vertices=24)),
    ("ising", dict(n=10, C=2.5)),
    ("chain", dict(n=300)),
    ("ldpc", dict(n=48, dv=3, dc=6)),
    ("stereo", dict(height=8, width=10, n_disp=5)),
)


def zoo_stream(n: int, *, seed: int = 0,
               kinds: Sequence[str] | None = None,
               slos: "float | Mapping[str, float] | None" = None
               ) -> Iterator[tuple]:
    """Yield ``n`` heterogeneous ``(kind, PGM)`` requests cycling the zoo.

    Kinds *and* sizes interleave (two size variants per kind, see
    ``_ZOO_VARIANTS``), so consecutive requests rarely share a bucket shape
    -- the scenario the admission and kind_affinity routing policies exist
    for. Deterministic: request ``i`` is generated with seed
    ``1000 * seed + i``, so two streams with equal ``(n, seed, kinds)``
    are identical graph for graph. ``kinds`` filters the table to a
    subset (unknown names raise ``KeyError`` via the registry).

    ``slos`` attaches per-request latency budgets for the SLA serving
    tier: a float applies one budget to everything, a mapping sets one
    per kind (missing kinds get no deadline). Items then come as
    ``(kind, PGM, slo_s)`` triples -- strip the kind and they feed
    straight into the ``(rid, pgm, slo)``-aware serving stack as
    ``(None, pgm, slo)``."""
    variants = _ZOO_VARIANTS
    if kinds is not None:
        for k in kinds:
            WORKLOADS.lookup(k)        # fail fast on unknown kinds
        variants = tuple((k, kw) for k, kw in _ZOO_VARIANTS if k in kinds)
        if not variants:
            raise ValueError(f"no zoo variants left after filtering {kinds}")
    for i in range(n):
        kind, kw = variants[i % len(variants)]
        pgm = WORKLOADS[kind](seed=1000 * seed + i, **kw)
        if slos is None:
            yield kind, pgm
        elif isinstance(slos, Mapping):
            yield kind, pgm, slos.get(kind)
        else:
            yield kind, pgm, float(slos)
