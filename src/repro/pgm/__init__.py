from repro.pgm.datasets import (chain_graph, ising_grid, ising_grid_fast,
                                loop_graph, protein_like_graph, small_ising)

__all__ = ["ising_grid", "ising_grid_fast", "chain_graph", "loop_graph",
           "protein_like_graph", "small_ising"]
