from repro.pgm.datasets import (LDPCInstance, StereoInstance, WORKLOADS,
                                chain_graph, get_workload, ising_grid,
                                ising_grid_fast, ldpc_code, ldpc_graph,
                                list_workloads, loop_graph,
                                protein_like_graph, register_workload,
                                small_ising, stereo_graph, stereo_mrf,
                                zoo_stream)

__all__ = ["LDPCInstance", "StereoInstance", "WORKLOADS", "chain_graph",
           "get_workload", "ising_grid", "ising_grid_fast", "ldpc_code",
           "ldpc_graph", "list_workloads", "loop_graph",
           "protein_like_graph", "register_workload", "small_ising",
           "stereo_graph", "stereo_mrf", "zoo_stream"]
