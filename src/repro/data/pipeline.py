"""Deterministic, shardable synthetic LM data pipeline.

Every batch is a pure function of (seed, step), so the pipeline "cursor" in
a checkpoint is just the step counter -- restart-exact resume on any mesh
size (batches are generated per-host then device_put against the batch
sharding; no cross-host coordination needed).

Token stream: Zipf-distributed ids over the vocab with a Markov bigram kick
so the loss has learnable structure (pure uniform tokens give a flat loss
-- useless for the convergence examples).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, InputShape


def make_batch_specs(cfg: ArchConfig, shape: InputShape,
                     dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for one *global* training batch (see launch.dryrun
    for the per-shape serve variants)."""
    b, s = shape.global_batch, shape.seq_len
    specs = {}
    if cfg.frontend == "vision":
        t = cfg.n_frontend_tokens
        specs["frontend_embeds"] = jax.ShapeDtypeStruct(
            (b, t, cfg.d_model), dtype)
        s = s - t                       # total sequence stays shape.seq_len
    if cfg.frontend == "audio":
        specs["frontend_embeds"] = jax.ShapeDtypeStruct(
            (b, s, cfg.d_model), dtype)
    specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return specs


@dataclasses.dataclass
class SyntheticLM:
    cfg: ArchConfig
    shape: InputShape
    seed: int = 0

    def batch(self, step: int) -> Dict[str, jax.Array]:
        cfg, shape = self.cfg, self.shape
        key = jax.random.fold_in(jax.random.key(self.seed), step)
        ks = jax.random.split(key, 4)
        b, s = shape.global_batch, shape.seq_len
        extra = {}
        if cfg.frontend == "vision":
            t = cfg.n_frontend_tokens
            extra["frontend_embeds"] = 0.02 * jax.random.normal(
                ks[2], (b, t, cfg.d_model), jnp.bfloat16)
            s = s - t
        if cfg.frontend == "audio":
            extra["frontend_embeds"] = 0.02 * jax.random.normal(
                ks[2], (b, s, cfg.d_model), jnp.bfloat16)
        # Zipf-ish marginal: id = floor(v * u^3) biases mass to small ids.
        u = jax.random.uniform(ks[0], (b, s + 1))
        toks = jnp.minimum((cfg.vocab * u ** 3).astype(jnp.int32),
                           cfg.vocab - 1)
        # Markov kick: with prob .5, token t+1 = (token t * 7 + 13) % vocab
        # -- a fixed learnable bigram rule.
        coin = jax.random.bernoulli(ks[1], 0.5, (b, s + 1))
        nxt = (toks * 7 + 13) % cfg.vocab
        toks = jnp.where(coin, jnp.roll(nxt, 1, axis=1), toks)
        return dict(extra, tokens=toks[:, :s],
                    labels=toks[:, 1:s + 1].astype(jnp.int32))
