from repro.roofline.analysis import (HW, RooflineReport, analyze_compiled,
                                     collective_bytes, model_flops)
from repro.roofline.jaxpr_cost import Cost, jaxpr_cost, trace_cost
from repro.roofline.kernel_model import (fused_update_cost, gpu_padded_shape,
                                         predicted_intensity, round_cost)

__all__ = ["HW", "RooflineReport", "analyze_compiled", "collective_bytes",
           "model_flops", "Cost", "jaxpr_cost", "trace_cost",
           "fused_update_cost", "gpu_padded_shape", "predicted_intensity",
           "round_cost"]
