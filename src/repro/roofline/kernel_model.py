"""Hand cost model of the fused message-update kernel (the tuning contract).

The fused kernels (``repro.kernels.message_update`` on TPU,
``repro.kernels.triton_update`` on GPU) promise **3 reads + 2 writes per
edge**: pairwise table, prelude and old messages stream in; new messages
and the residual stream out; plus the 1-byte destination-state mask.
Per edge of S (padded) states at ``itemsize`` b:

    bytes = (S^2 + 3*S + 1) * b  +  S          # 3 reads + 2 writes + mask

Flops are hand-counted from the kernel body, one flop per output element
per arithmetic op (the same convention ``repro.roofline.jaxpr_cost``
uses), so the jaxpr walker and this model are directly comparable:

    sum-product:  scores add S^2, src max-reduce S^2, shift-sub S^2,
                  exp S^2, sum-reduce S^2                    -> 5*S^2
                  + normalize/residual/mask tail              ~ 24*S + 6
    max-product:  scores add S^2, src max-reduce S^2          -> 2*S^2
                  + normalize/residual/mask tail              ~ 14*S + 1

The O(S) tail constants are fitted once against the traced kernel (exact
at time of writing); ``tests/test_roofline.py`` pins model-vs-jaxpr
agreement so neither the kernel body nor the walker can drift silently.
``benchmarks/bench_kernel.py`` uses ``predicted_intensity`` as the
autotune target and records predicted-vs-measured per scheduler.
"""

from __future__ import annotations

from repro.roofline.jaxpr_cost import Cost

__all__ = ["fused_update_cost", "predicted_intensity", "gpu_padded_shape",
           "round_cost"]

_FLOPS_PER_EDGE = {
    # semiring -> (S^2 coefficient, S coefficient, constant)
    "sum": (5.0, 24.0, 6.0),
    "max": (2.0, 14.0, 1.0),
}


def _next_pow2(n: int) -> int:
    n = max(int(n), 1)
    return 1 << (n - 1).bit_length()


def gpu_padded_shape(n_edges: int, n_states: int, dtype_bytes: int = 4, *,
                     blk_e: int | None = None):
    """The shapes the GPU kernel actually launches with: states padded to
    the next power of two (>= 2, Triton tile constraint), edges to a
    multiple of the picked block. Returns ``(e_pad, s_pad, blk)``."""
    from repro.kernels.triton_update import (_MIN_BLK, next_pow2,
                                             pick_block_edges_gpu)
    s_pad = max(2, next_pow2(n_states))
    blk = blk_e or pick_block_edges_gpu(s_pad, dtype_bytes)
    blk = max(_MIN_BLK, min(blk, next_pow2(n_edges)))
    e_pad = ((n_edges + blk - 1) // blk) * blk
    return e_pad, s_pad, blk


def fused_update_cost(n_edges: int, n_states: int, *, dtype_bytes: int = 4,
                      semiring: str = "sum", padded: bool = False) -> Cost:
    """3-read/2-write model cost of one fused update over ``n_edges`` edges
    of ``n_states`` states. With ``padded=True`` the GPU kernel's internal
    padding (power-of-two states, block-multiple edges) is applied first,
    predicting the *launched* cost rather than the logical one."""
    if semiring not in _FLOPS_PER_EDGE:
        raise ValueError(f"unknown semiring {semiring!r}; "
                         f"expected one of {sorted(_FLOPS_PER_EDGE)}")
    e, s = int(n_edges), int(n_states)
    if padded:
        e, s, _ = gpu_padded_shape(e, s, dtype_bytes)
    a, b, c = _FLOPS_PER_EDGE[semiring]
    flops = e * (a * s * s + b * s + c)
    byts = e * ((s * s + 3 * s + 1) * dtype_bytes + s)
    return Cost(float(flops), float(byts))


def predicted_intensity(n_states: int, *, dtype_bytes: int = 4,
                        semiring: str = "sum", padded: bool = False) -> float:
    """Model arithmetic intensity (flops/byte) of the fused update; edge
    count cancels, so this is a pure function of the state count and width.
    The roofline ridge point (peak_flops / hbm_bw, ~240 f/B on a v5e,
    ~295 f/B on an H100) is far above every BP state count -- the update is
    memory-bound everywhere, which is why the 3-read/2-write fusion (vs the
    reference path's three separate round trips) is the whole win."""
    c = fused_update_cost(1 if not padded else 64, n_states,
                          dtype_bytes=dtype_bytes, semiring=semiring,
                          padded=padded)
    return c.flops / c.bytes


def round_cost(pgm, scheduler, update_fn, *, eps: float = 1e-3,
               rng=None) -> Cost:
    """Jaxpr-walk cost of ONE full engine round -- fused update + residual
    gate + scheduler frontier selection + commit -- for a given scheduler
    instance and update backend. This is what ``bench_kernel`` measures per
    scheduler: the update kernel's intensity diluted by whatever selection
    machinery the scheduler adds (top-k, per-queue bisection, RNG)."""
    import jax
    import jax.numpy as jnp

    from repro.core import messages as M
    from repro.roofline.jaxpr_cost import trace_cost

    logm = M.init_messages(pgm)
    sstate = scheduler.init(pgm)
    key = jax.random.key(0) if rng is None else rng

    def one_round(logm, sstate, key):
        cand, r = update_fn(pgm, logm)
        unconverged = jnp.sum((r >= eps) & pgm.edge_mask).astype(jnp.int32)
        frontier, sstate = scheduler.select(pgm, r, eps, key, sstate,
                                            unconverged)
        return M.apply_frontier(logm, cand, frontier), sstate

    return trace_cost(one_round, logm, sstate, key)
