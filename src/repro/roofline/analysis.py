"""Roofline term derivation from compiled XLA artifacts.

Three terms per (arch x shape x mesh) cell, per the harness spec:

    compute    = HLO_FLOPs      / (peak_FLOP/s)        [per chip]
    memory     = HLO_bytes      / (HBM_bw)             [per chip]
    collective = collective_B   / (link_bw)            [per chip]

``compiled.cost_analysis()`` reports the SPMD-partitioned module, i.e.
*per-device* flops/bytes -- the roofline divides by per-chip peaks, no
further /chips needed. Collective bytes are NOT in cost_analysis: we parse
``compiled.as_text()`` (post-partitioning HLO) and sum the *result shapes*
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (bytes-through-the-link proxy; all-reduce counts 2x for
the reduce+broadcast halves of a ring).

Hardware constants: TPU v5e -- 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per the harness spec).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12        # bf16 per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    link_bw: float = 50e9             # bytes/s per ICI link
    hbm_bytes: float = 16e9           # v5e capacity


_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_WHILE_RE = re.compile(r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*"
                       r"body=%?([\w.\-]+)")
_INT_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, list]:
    """Name -> body lines. A computation head is any top-level line ending
    with '{' whose first token is the computation name (possibly after
    'ENTRY'). Tuple-typed parameter lists may contain nested parens, so no
    attempt is made to parse the signature."""
    comps: Dict[str, list] = {}
    cur = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            if s.endswith("{") and not s.startswith("HloModule"):
                toks = s.split()
                if not toks:
                    continue
                name = toks[1] if toks[0] == "ENTRY" and len(toks) > 1 \
                    else toks[0]
                name = name.lstrip("%").split("(")[0].rstrip(",")
                if name:
                    cur = name
                    comps[cur] = []
        else:
            if s == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _local_collectives(lines) -> Dict[str, float]:
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in lines:
        eq = line.find("=")
        if eq < 0:
            continue
        for kind in _COLLECTIVES:
            # find the op-use site (name followed by '('), searching after
            # '=' so lhs value names like %all-reduce.183 don't match
            pos, is_start, skip = -1, False, False
            i = line.find(kind, eq)
            while i >= 0:
                after = line[i + len(kind):]
                if after.startswith("("):
                    pos = i
                    break
                if after.startswith("-start("):
                    pos, is_start = i, True
                    break
                if after.startswith("-done"):
                    skip = True     # async pair counted at -start
                    break
                i = line.find(kind, i + 1)
            if skip:
                break
            if pos < 0:
                continue
            head = line[eq + 1:pos]
            total = sum(_shape_bytes(dt, dims)
                        for dt, dims in _SHAPE_RE.findall(head))
            if is_start:
                total //= 2     # async form: (operand, result) tuple on lhs
            if kind == "all-reduce":
                total *= 2      # ring all-reduce moves ~2x the payload
            out[kind] += float(total)
            break
    return out


def _trip_count(cond_lines) -> float:
    """Trip count of a while loop from its condition computation: the
    largest integer literal compared against (scan counters compare the
    induction variable with constant(length))."""
    consts = [int(m.group(1)) for line in cond_lines
              for m in _INT_CONST_RE.finditer(line)]
    return float(max(consts)) if consts else 1.0


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Collective bytes from post-SPMD HLO text, scan/while-aware.

    XLA keeps each while body as ONE computation regardless of trip count,
    so collectives inside scan-stacked layers must be multiplied by the
    loop's trip count (recovered from the paired condition computation's
    integer constant). Nested whiles multiply through.
    """
    comps = _split_computations(hlo_text)
    local = {name: _local_collectives(lines) for name, lines in comps.items()}
    # while edges: computation -> [(cond, body)]
    edges: Dict[str, list] = {name: [] for name in comps}
    for name, lines in comps.items():
        for line in lines:
            m = _WHILE_RE.search(line)
            if m:
                edges[name].append((m.group(1), m.group(2)))

    memo: Dict[str, Dict[str, float]] = {}

    def total(name: str, depth: int = 0) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        if depth > 64 or name not in comps:
            return {k: 0.0 for k in _COLLECTIVES}
        acc = dict(local.get(name, {k: 0.0 for k in _COLLECTIVES}))
        for cond, body in edges.get(name, []):
            trips = _trip_count(comps.get(cond, []))
            sub = total(body, depth + 1)
            for k in _COLLECTIVES:
                acc[k] = acc.get(k, 0.0) + trips * sub.get(k, 0.0)
        memo[name] = acc
        return acc

    # entry = computation not referenced as body/cond of any while and not a
    # fusion; robust fallback: sum over roots (computations never used as a
    # while body/cond).
    used = {c for lst in edges.values() for pair in lst for c in pair}
    roots = [n for n in comps if n not in used]
    out = {k: 0.0 for k in _COLLECTIVES}
    for r in roots:
        t = total(r)
        for k in _COLLECTIVES:
            out[k] += t.get(k, 0.0)
    out["total"] = float(sum(out[k] for k in _COLLECTIVES))
    return out


@dataclasses.dataclass
class RooflineReport:
    flops: float                 # per-device logical flops (jaxpr walk)
    hbm_bytes: float             # per-device traffic (jaxpr walk + weights)
    coll_bytes: float            # per-device collective bytes (HLO walk)
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float           # 6*N(_active)*D (train) / 2*N*D (serve)
    useful_ratio: float          # model_flops / global logical flops
    coll_breakdown: Dict[str, float]
    xla_flops_once: float = 0.0  # raw cost_analysis (scan bodies counted 1x)
    memory_per_device: Optional[dict] = None

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze_compiled(compiled, *, n_devices: int,
                     logical_flops: float = 0.0,
                     logical_bytes: float = 0.0,
                     param_bytes: float = 0.0,
                     model_axis: int = 1,
                     model_flops_global: float = 0.0,
                     hw: HW = HW()) -> RooflineReport:
    """Roofline terms for one compiled cell.

    logical_flops/bytes: GLOBAL counts from the jaxpr walker (exact w.r.t.
    scan trip counts). param_bytes: total parameter bytes -- every step
    streams the (model-axis-sharded) weights from HBM at least once, which
    the /n_devices division would otherwise hide from the memory term.
    """
    cost = compiled.cost_analysis()
    xla_flops = float(cost.get("flops", 0.0))
    coll = collective_bytes(compiled.as_text())
    flops_dev = logical_flops / n_devices
    bytes_dev = logical_bytes / n_devices + param_bytes / max(model_axis, 1)
    t_c = flops_dev / hw.peak_flops
    t_m = bytes_dev / hw.hbm_bw
    t_x = coll["total"] / hw.link_bw
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    useful = (model_flops_global / logical_flops
              if logical_flops > 0 and model_flops_global > 0 else 0.0)
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "peak_ok_16GB": bool(ma.temp_size_in_bytes
                                 + ma.argument_size_in_bytes < hw.hbm_bytes),
        }
    except Exception:
        pass
    return RooflineReport(
        flops=flops_dev, hbm_bytes=bytes_dev, coll_bytes=coll["total"],
        t_compute=t_c, t_memory=t_m, t_collective=t_x, bottleneck=bottleneck,
        model_flops=model_flops_global, useful_ratio=useful,
        coll_breakdown=coll, xla_flops_once=xla_flops,
        memory_per_device=mem)


def model_flops(param_specs: Any, n_tokens: float, *, cfg=None,
                kind: str = "train") -> float:
    """6*N*D (dense) / 6*N_active*D (MoE), D = processed tokens.

    kind: train -> 6ND (fwd+bwd); prefill/decode -> 2ND (fwd only).
    Expert leaves (3-D, leading dim = n_experts) are scaled by the active
    fraction (top_k + shared) / n_experts.
    """
    import jax

    total = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(param_specs)[0]:
        n = float(np.prod(leaf.shape))
        names = "/".join(str(getattr(p, "key", p)) for p in path)
        if cfg is not None and cfg.n_experts and leaf.ndim >= 3 and \
                ("moe" in names and "shared" not in names
                 and "router" not in names):
            # stacked experts: (L, E, a, b) or (E, a, b)
            frac = cfg.experts_per_token / cfg.n_experts
            n *= frac
        total += n
    mult = 6.0 if kind == "train" else 2.0
    return mult * total * n_tokens
