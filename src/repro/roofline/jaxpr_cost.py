"""Jaxpr-level FLOP / memory-traffic counter (scan- and while-aware).

Why not ``compiled.cost_analysis()``: XLA's aggregate cost analysis counts
each ``while`` body ONCE, so scan-stacked layer stacks (the only way to keep
HLO bounded at 512 devices) undercount by a factor of n_layers. The jaxpr
still carries static trip counts, so walking it gives exact logical counts:

  flops:
    dot_general     2 * prod(batch) * M * N * K        (FMA = 2)
    conv            2 * out_elems * kernel_elems_per_out
    elementwise/reduce: 1 per output element (unary/binary alike)
    scan            body * length;  while: body * trips_hint
  bytes (perfect-fusion traffic model -- optimistic lower bound, documented):
    dot/conv        lhs + rhs + out
    gather/scatter/dynamic-(update-)slice/sort/top_k: in + out
    reduce/cumsum   in + out
    scan            (consts + carry) * length + xs + ys   (carry re-written
                    every iteration; xs/ys stream once)
    elementwise     0 (assumed fused into a producer)

Counts are GLOBAL (logical, pre-SPMD); callers divide by the device count
under the perfect-sharding assumption and should treat per-device numbers
as optimistic where the sharding resolver fell back to replication (those
cells are flagged by the resolver).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict

import jax
import numpy as np
from jax import core


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0

    def __add__(self, o: "Cost") -> "Cost":
        return Cost(self.flops + o.flops, self.bytes + o.bytes)

    def __mul__(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k)


def _nbytes(aval) -> float:
    if not hasattr(aval, "shape"):
        return 0.0
    return float(np.prod(aval.shape, dtype=np.float64)
                 * np.dtype(aval.dtype).itemsize) if aval.shape else \
        float(np.dtype(aval.dtype).itemsize)


def _nelems(aval) -> float:
    return float(np.prod(aval.shape, dtype=np.float64)) \
        if getattr(aval, "shape", ()) else 1.0


_MEMORY_PRIMS = {
    "gather", "scatter", "scatter-add", "scatter_add", "dynamic_slice",
    "dynamic_update_slice", "sort", "top_k", "cumsum", "cumlogsumexp",
    "cummax", "argmax", "argmin", "iota", "rev", "transpose", "broadcast",
}
_REDUCE_PRIMS = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                 "reduce_and", "reduce_or", "reduce_precision", "argmax",
                 "argmin"}


def _dot_cost(eqn) -> Cost:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    batch = np.prod([lhs.shape[i] for i in lb], dtype=np.float64) if lb else 1
    k = np.prod([lhs.shape[i] for i in lc], dtype=np.float64) if lc else 1
    m = np.prod([d for i, d in enumerate(lhs.shape)
                 if i not in set(lc) | set(lb)], dtype=np.float64)
    n = np.prod([d for i, d in enumerate(rhs.shape)
                 if i not in set(rc) | set(rb)], dtype=np.float64)
    flops = 2.0 * batch * m * n * k
    byts = _nbytes(lhs) + _nbytes(rhs) + sum(_nbytes(o.aval)
                                             for o in eqn.outvars)
    return Cost(flops, byts)


def _conv_cost(eqn) -> Cost:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    kernel = np.prod(rhs.shape, dtype=np.float64)
    out_spatial = np.prod(out.shape, dtype=np.float64)
    # per output element: one MAC per kernel element / out-channels
    flops = 2.0 * out_spatial * kernel / max(rhs.shape[-1], 1)
    byts = sum(_nbytes(v.aval) for v in eqn.invars) + _nbytes(out)
    return Cost(flops, byts)


def jaxpr_cost(jaxpr: core.Jaxpr, *, while_trips: float = 1.0) -> Cost:
    total = Cost()
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            total = total + _dot_cost(eqn)
        elif prim == "conv_general_dilated":
            total = total + _conv_cost(eqn)
        elif prim == "scan":
            body = jaxpr_cost(eqn.params["jaxpr"].jaxpr,
                              while_trips=while_trips)
            length = float(eqn.params["length"])
            n_consts = eqn.params["num_consts"]
            n_carry = eqn.params["num_carry"]
            carry_b = sum(_nbytes(v.aval)
                          for v in eqn.invars[n_consts:n_consts + n_carry])
            xs_b = sum(_nbytes(v.aval) for v in eqn.invars[n_consts + n_carry:])
            ys_b = sum(_nbytes(v.aval) for v in eqn.outvars[n_carry:])
            total = total + body * length
            total.bytes += carry_b * length + xs_b + ys_b
        elif prim == "while":
            body = jaxpr_cost(eqn.params["body_jaxpr"].jaxpr,
                              while_trips=while_trips)
            total = total + body * while_trips
        elif prim == "pallas_call":
            # Fused-kernel contract: the kernel streams each outer operand
            # and result through HBM exactly once (perfect fusion is the
            # *definition* of a fused kernel, not an optimistic assumption
            # here), so bytes = sum of the call's in/out avals -- e.g. the
            # message-update kernel's 3-read/2-write model. Flops come from
            # the kernel body jaxpr, once per grid step; the body's own
            # byte counts (ref get/swap traffic) are on-chip and ignored.
            inner = eqn.params["jaxpr"]
            ij = inner.jaxpr if hasattr(inner, "jaxpr") else inner
            body = jaxpr_cost(ij, while_trips=while_trips)
            grid = eqn.params["grid_mapping"].grid
            steps = float(np.prod([d for d in grid], dtype=np.float64)) \
                if grid else 1.0
            total.flops += body.flops * steps
            total.bytes += sum(_nbytes(v.aval) for v in eqn.invars
                               if hasattr(v, "aval")) \
                + sum(_nbytes(o.aval) for o in eqn.outvars)
        elif prim == "cond":
            branches = [jaxpr_cost(b.jaxpr, while_trips=while_trips)
                        for b in eqn.params["branches"]]
            # count the most expensive branch
            total = total + max(branches, key=lambda c: c.flops + c.bytes)
        elif (inner := (eqn.params.get("jaxpr")
                        or eqn.params.get("call_jaxpr")
                        or eqn.params.get("fun_jaxpr"))) is not None:
            # pjit / remat / remat2 / custom_vjp / closed_call / ...:
            # any jaxpr-carrying primitive recurses
            ij = inner.jaxpr if hasattr(inner, "jaxpr") else inner
            total = total + jaxpr_cost(ij, while_trips=while_trips)
        elif prim in ("get", "swap", "addupdate"):
            # Pallas ref reads/writes: on-chip register/SMEM movement inside
            # a kernel body; the HBM traffic is charged at the pallas_call.
            pass
        elif prim in _REDUCE_PRIMS:
            total.flops += sum(_nelems(v.aval) for v in eqn.invars)
            total.bytes += sum(_nbytes(v.aval) for v in eqn.invars) \
                + sum(_nbytes(o.aval) for o in eqn.outvars)
        elif prim in _MEMORY_PRIMS:
            total.bytes += sum(_nbytes(v.aval) for v in eqn.invars
                               if hasattr(v, "aval")) \
                + sum(_nbytes(o.aval) for o in eqn.outvars)
        else:
            # elementwise & friends: 1 flop/output element, fused (0 bytes)
            total.flops += sum(_nelems(o.aval) for o in eqn.outvars)
    return total


def trace_cost(fn, *args, while_trips: float = 1.0, **kwargs) -> Cost:
    """Trace ``fn`` with ShapeDtypeStruct args and count its jaxpr."""
    closed = jax.make_jaxpr(partial(fn, **kwargs) if kwargs else fn)(*args)
    return jaxpr_cost(closed.jaxpr, while_trips=while_trips)
