"""Frontier-based BP runner (paper Algorithm 1) as one ``lax.while_loop``.

Each loop round performs:
  1. one full candidate pass  cand = f_BP(m)          (all edges; static shape)
  2. residuals r = ||cand - m||_inf                   (Eq. 4)
  3. unconverged = #{r >= eps}  -> IsConverged
  4. frontier   = scheduler.select(r, ...)            -> GenerateFrontier
  5. m          = where(frontier, cand, m)            -> Update

On the GPU the frontier is compacted so small frontiers cost less; under XLA
SPMD shapes are static, so a round costs one full sweep regardless of
frontier size. We therefore report both ``rounds`` (bulk sweeps == wall-time
proxy) and ``updates`` (committed messages == useful-work proxy); the paper's
speed claims map to ``rounds`` and its work-efficiency claims to ``updates``.

A fixed-size history buffer records per-round unconverged counts so the
cumulative-convergence figures (paper Figs 2/4) can be reproduced without
host round-trips.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import messages as M
from repro.core.graph import PGM
from repro.core.schedulers.base import Scheduler


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BPResult:
    beliefs: jax.Array          # (V, S) log-marginals
    logm: jax.Array             # (E, S) final messages
    rounds: jax.Array           # () int32: bulk sweeps executed
    updates: jax.Array          # () int64-ish f32: total committed messages
    converged: jax.Array        # () bool
    max_residual: jax.Array     # () f32 at exit
    unconverged_history: jax.Array  # (max_rounds,) int32, -1 past exit
    sched_state: Any            # scheduler carry (for chunked resume)


@partial(jax.jit, static_argnames=("scheduler", "max_rounds", "damping",
                                   "update_fn", "track_history"))
def run_bp(pgm: PGM,
           scheduler: Scheduler,
           rng: jax.Array,
           *,
           eps: float = 1e-3,
           max_rounds: int = 2000,
           damping: float = 0.0,
           update_fn: Callable = M.ref_update,
           track_history: bool = True,
           _init_logm: jax.Array | None = None,
           _init_state: Any = None) -> BPResult:
    logm0 = M.init_messages(pgm) if _init_logm is None else _init_logm
    hist0 = jnp.full((max_rounds if track_history else 1,), -1, jnp.int32)

    def cond(carry):
        _, _, _, rounds, done, _, _, _ = carry
        return (~done) & (rounds < max_rounds)

    def body(carry):
        logm, sstate, rng, rounds, done, updates, hist, _ = carry
        rng, sel_key = jax.random.split(rng)
        cand, r = update_fn(pgm, logm)
        unconverged = jnp.sum((r >= eps) & pgm.edge_mask).astype(jnp.int32)
        frontier, sstate = scheduler.select(pgm, r, eps, sel_key, sstate,
                                            unconverged)
        # Converged -> commit nothing (IsConverged precedes Update in Alg. 1).
        newly_done = unconverged == 0
        frontier = frontier & ~newly_done
        logm = M.apply_frontier(logm, cand, frontier, damping)
        # Residual Splash: h-1 extra masked sweeps inside the same frontier.
        for _ in range(scheduler.inner_sweeps - 1):
            cand, _ = update_fn(pgm, logm)
            logm = M.apply_frontier(logm, cand, frontier, damping)
        updates = updates + jnp.sum(frontier).astype(jnp.float32) \
            * scheduler.inner_sweeps
        if track_history:
            hist = hist.at[rounds].set(unconverged)
        rounds = rounds + jnp.where(newly_done, 0,
                                    jnp.int32(scheduler.inner_sweeps))
        max_r = jnp.max(r)
        return (logm, sstate, rng, rounds, newly_done, updates, hist, max_r)

    sstate0 = scheduler.init(pgm) if _init_state is None else _init_state
    carry0 = (logm0, sstate0, rng, jnp.int32(0),
              jnp.asarray(False), jnp.float32(0.0), hist0, jnp.float32(jnp.inf))
    logm, sstate, _, rounds, done, updates, hist, max_r = jax.lax.while_loop(
        cond, body, carry0)
    return BPResult(beliefs=M.beliefs(pgm, logm), logm=logm, rounds=rounds,
                    updates=updates, converged=done, max_residual=max_r,
                    unconverged_history=hist, sched_state=sstate)
