"""Deprecated single-graph entry point for frontier-based BP.

The loop (paper Algorithm 1) lives in ``repro.core.engine``; ``run_bp`` is a
thin compatibility wrapper with exact-trajectory parity -- the engine runs
the identical ``lax.while_loop`` body, so ``logm``/``rounds``/``updates``
match the historic implementation bit-for-bit. New code should use::

    engine = BPEngine(BPConfig(scheduler="rnbp", eps=1e-3, max_rounds=2000))
    res = engine.run(pgm, rng)

and, for resumable execution, ``engine.init`` / ``engine.step`` instead of
the old ``_init_logm``/``_init_state`` backdoor (still honored here for
callers that carried state manually).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable

import jax

from repro.core import messages as M
from repro.core.engine import BPConfig, BPEngine, BPResult  # noqa: F401
from repro.core.graph import PGM
from repro.core.schedulers.base import Scheduler

__all__ = ["run_bp"]


def run_bp(pgm: PGM,
           scheduler: Scheduler,
           rng: jax.Array,
           *,
           eps: float = 1e-3,
           max_rounds: int = 2000,
           damping: float = 0.0,
           update_fn: Callable = M.ref_update,
           track_history: bool = True,
           _init_logm: jax.Array | None = None,
           _init_state: Any = None) -> BPResult:
    """Deprecated wrapper: ``BPEngine(BPConfig(...)).run(pgm, rng)``."""
    warnings.warn(
        "run_bp is deprecated: use repro.core.BPEngine with a BPConfig "
        "(config-driven scheduler/backend, chunked resume via init/step)",
        DeprecationWarning, stacklevel=2)
    engine = BPEngine(BPConfig(
        scheduler=scheduler, eps=eps, max_rounds=max_rounds, damping=damping,
        backend=update_fn, history=track_history))
    state = engine.init(pgm, rng)
    if _init_logm is not None:
        state = dataclasses.replace(state, logm=_init_logm)
    if _init_state is not None:
        state = dataclasses.replace(state, sched_state=_init_state)
    return engine.run(pgm, state=state)
