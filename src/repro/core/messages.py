"""Log-space sum-product message math (pure jnp reference path).

The per-round compute is exactly the paper's Eq. (2), vectorized over *all*
directed edges (static shapes; the scheduler masks which results commit):

    m_{i->j}(x_j) oc sum_{x_i} psi_ij(x_i, x_j) psi_i(x_i)
                     prod_{k in G(i)\\j} m_{k->i}(x_i)

In log space with a per-vertex "incoming sum" cache:

    vsum[i]   = sum over incoming edges e'=(k->i) of logm[e']        (segment_sum)
    pre[e]    = log_psi_v[src] + vsum[src] - logm[rev(e)]            (exclude j->i)
    cand[e,j] = LSE_{x_i}( log_psi_e[e, x_i, x_j] + pre[e, x_i] )    (hot spot)

``cand`` is then normalized (LSE over valid dst states == 0). The LSE hot spot
is what the Pallas kernel in ``repro.kernels.message_update`` implements; this
module is the oracle (``ref.py`` re-exports from here) and the CPU path.

Residual (paper Eq. 4): r(m) = || f_BP(m) - m ||_inf over valid states.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.graph import NEG_INF, PGM


def masked_logsumexp(x: jax.Array, mask: jax.Array, axis: int) -> jax.Array:
    """LSE over ``axis`` counting only ``mask`` entries; NEG_INF-safe."""
    x = jnp.where(mask, x, NEG_INF)
    m = jnp.max(x, axis=axis, keepdims=True)
    m = jnp.maximum(m, NEG_INF)  # all-masked rows stay finite
    s = jnp.sum(jnp.where(mask, jnp.exp(x - m), 0.0), axis=axis)
    return jnp.squeeze(m, axis) + jnp.log(jnp.maximum(s, 1e-38))


def init_messages(pgm: PGM, dtype=jnp.float32) -> jax.Array:
    """Uniform messages over the *destination* vertex's valid states."""
    dst_mask = pgm.state_mask[pgm.edge_dst]                     # (E, S)
    n_dst = pgm.n_states[pgm.edge_dst].astype(dtype)            # (E,)
    logm = jnp.where(dst_mask, -jnp.log(n_dst)[:, None], NEG_INF)
    return logm.astype(dtype)


def vertex_logprod(pgm: PGM, logm: jax.Array) -> jax.Array:
    """(V, S) sum of incoming log-messages per vertex (the paper's per-vertex
    message product, in log space). Padded edges target the dummy vertex so
    they never pollute real sums; invalid states carry NEG_INF garbage which
    downstream masking discards."""
    contrib = jnp.where(pgm.edge_mask[:, None], logm, 0.0)
    return jax.ops.segment_sum(contrib, pgm.edge_dst,
                               num_segments=pgm.n_vertices)


def edge_prelude(pgm: PGM, logm: jax.Array,
                 vsum: jax.Array | None = None) -> jax.Array:
    """(E, S) per-edge source-side belief excluding the reverse message."""
    if vsum is None:
        vsum = vertex_logprod(pgm, logm)
    pre = (pgm.log_psi_v[pgm.edge_src]
           + vsum[pgm.edge_src]
           - logm[pgm.edge_rev])
    src_mask = pgm.state_mask[pgm.edge_src]
    return jnp.where(src_mask, pre, NEG_INF)


def propagate_ref(log_psi_e: jax.Array, pre: jax.Array) -> jax.Array:
    """The LSE hot spot: cand[e, xj] = LSE_xi(log_psi_e[e, xi, xj] + pre[e, xi]).

    Pure-jnp oracle for the Pallas kernel. Not normalized, not masked on dst.
    """
    scores = log_psi_e + pre[:, :, None]          # (E, S, S)
    m = jnp.max(scores, axis=1, keepdims=True)
    m = jnp.maximum(m, NEG_INF)
    s = jnp.sum(jnp.exp(scores - m), axis=1)
    return jnp.squeeze(m, 1) + jnp.log(jnp.maximum(s, 1e-38))


def normalize_messages(pgm: PGM, cand: jax.Array) -> jax.Array:
    """Normalize (LSE over valid dst states -> 0) and mask invalid states."""
    dst_mask = pgm.state_mask[pgm.edge_dst]
    z = masked_logsumexp(cand, dst_mask, axis=1)
    out = cand - z[:, None]
    return jnp.where(dst_mask, out, NEG_INF)


def compute_candidates(pgm: PGM, logm: jax.Array,
                       propagate=propagate_ref) -> jax.Array:
    """One full candidate-message pass f_BP(m) for every directed edge."""
    pre = edge_prelude(pgm, logm)
    cand = propagate(pgm.log_psi_e, pre)
    return normalize_messages(pgm, cand)


def normalize_and_residual(cand: jax.Array, logm: jax.Array,
                           dst_mask: jax.Array, edge_mask: jax.Array):
    """Shared tail of the jnp update paths (``ref_update`` and both
    ``repro.dist`` backends): normalize raw candidates (LSE over valid
    destination states -> 0, invalid states NEG_INF) and compute the (E,)
    L-inf residual vs the current messages (0 on padded edges). Takes
    explicit masks instead of a PGM so shard-local edge slices run the
    exact single-device math."""
    z = masked_logsumexp(cand, dst_mask, axis=1)
    cand = jnp.where(dst_mask, cand - z[:, None], NEG_INF)
    d = jnp.where(dst_mask, jnp.abs(cand - logm), 0.0)
    resid = jnp.where(edge_mask, jnp.max(d, axis=1), 0.0)
    return cand, resid


def residuals(pgm: PGM, logm: jax.Array, cand: jax.Array) -> jax.Array:
    """(E,) L-inf residual per directed edge; 0 on padded edges."""
    dst_mask = pgm.state_mask[pgm.edge_dst]
    d = jnp.where(dst_mask, jnp.abs(cand - logm), 0.0)
    r = jnp.max(d, axis=1)
    return jnp.where(pgm.edge_mask, r, 0.0)


def beliefs(pgm: PGM, logm: jax.Array) -> jax.Array:
    """(V, S) normalized log-marginals (paper Eq. 3)."""
    b = pgm.log_psi_v + vertex_logprod(pgm, logm)
    z = masked_logsumexp(b, pgm.state_mask, axis=1)
    b = b - z[:, None]
    return jnp.where(pgm.state_mask, b, NEG_INF)


def ref_update(pgm: PGM, logm: jax.Array):
    """One fused BP step: (candidate messages, residuals). Pure-jnp reference;
    the Pallas path (repro.kernels.ops.pallas_update) matches this signature."""
    pre = edge_prelude(pgm, logm)
    cand = propagate_ref(pgm.log_psi_e, pre)
    return normalize_and_residual(cand, logm, pgm.state_mask[pgm.edge_dst],
                                  pgm.edge_mask)


# ------------------------------------------------------ max-product (MAP) --

def propagate_max(log_psi_e: jax.Array, pre: jax.Array) -> jax.Array:
    """Max-product semiring: cand[e, xj] = max_xi(log_psi + pre). The paper
    (SSV) notes RnBP applies to other BP variants; scheduling is semiring-
    agnostic, so max-product reuses the whole frontier machinery."""
    return jnp.max(log_psi_e + pre[:, :, None], axis=1)


def max_product_update(pgm: PGM, logm: jax.Array):
    """ref_update for MAP inference (max-product). Messages renormalized to
    max 0 over valid states (the standard max-product normalization)."""
    pre = edge_prelude(pgm, logm)
    cand = propagate_max(pgm.log_psi_e, pre)
    dst_mask = pgm.state_mask[pgm.edge_dst]
    cand = jnp.where(dst_mask, cand, NEG_INF)
    z = jnp.max(jnp.where(dst_mask, cand, NEG_INF), axis=1)
    cand = jnp.where(dst_mask, cand - z[:, None], NEG_INF)
    return cand, residuals(pgm, logm, cand)


def map_assignment(pgm: PGM, logm: jax.Array) -> jax.Array:
    """(V,) argmax decoding of max-product beliefs."""
    b = pgm.log_psi_v + vertex_logprod(pgm, logm)
    b = jnp.where(pgm.state_mask, b, NEG_INF)
    return jnp.argmax(b, axis=1)


def apply_frontier(logm: jax.Array, cand: jax.Array,
                   frontier: jax.Array, damping: float = 0.0) -> jax.Array:
    """Commit candidate messages on frontier edges (static-shape analogue of
    the paper's compacted update). Optional damping (beyond-paper knob):
    new = (1-d)*cand + d*old, in log space (geometric damping)."""
    if damping > 0.0:
        cand = (1.0 - damping) * cand + damping * logm
    return jnp.where(frontier[:, None], cand, logm)
