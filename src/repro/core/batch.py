"""Batched multi-graph BP engine: vmap-able PGM buckets + padded batches.

A single sparse PGM rarely saturates a many-core device; the serving
workload is *many independent* inference problems per device step. This
module provides the batching primitive every scaling layer builds on:

- ``BatchedPGM``: B same-shape graphs stacked leaf-wise. The element ``PGM``
  keeps bucket-ceiling *static* metadata (shared treedef / one compilation)
  while per-graph real sizes ride along as traced ``(B,)`` scalars, which the
  schedulers consume via ``traced_edge_count``/``traced_vertex_count``.
- ``bucket_pgms``: groups heterogeneous graphs into buckets keyed by
  power-of-two (edge, state) ceilings, bounding padding waste at ~2x per
  axis, then pads each graph to its bucket shape with ``pad_pgm``.
The batched *loop* lives in ``repro.core.engine`` (one gated
``lax.while_loop`` whose per-slice body reproduces the solo trajectory
exactly; the message update runs on the bucket's *disjoint union* --
``BatchedPGM.folded()`` offsets vertex/edge ids so B graphs become one
(B*E)-edge graph riding the unmodified single-graph update, Pallas kernel
included). ``run_bp_batch`` / ``run_bp_many`` remain here as deprecated
wrappers around ``BPEngine``.
"""

from __future__ import annotations

import dataclasses
import threading
import warnings
from collections import deque
from typing import (Any, Callable, Deque, Dict, List, Mapping, Sequence,
                    Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import EDGE_PAD, PGM, VERTEX_PAD, pad_pgm_arrays
from repro.core.schedulers.base import Scheduler

__all__ = ["BatchedPGM", "Bucket", "RidgeEffort", "RoundsHistory",
           "batch_keys", "bucket_key", "bucket_pgms", "bucket_shape",
           "group_ceilings", "run_bp_batch", "run_bp_many"]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pow2_ceil(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BatchedPGM:
    """B graphs padded to one (E, V, S) bucket shape, stacked leaf-wise.

    ``pgm`` is an element-``PGM`` whose array leaves carry a leading batch
    axis -- ``edge_src (B, E)``, ``log_psi_e (B, E, S, S)``, ... -- and whose
    static ints are the bucket ceilings. Slicing out ``graph(i)`` yields a
    standalone ``PGM`` that runs through plain ``run_bp`` and reproduces the
    batched trajectory of graph ``i`` exactly.
    """

    pgm: PGM

    @property
    def size(self) -> int:
        return self.pgm.edge_src.shape[0]

    @property
    def n_edges(self) -> int:
        return self.pgm.edge_src.shape[1]

    @property
    def n_vertices(self) -> int:
        return self.pgm.log_psi_v.shape[1]

    @property
    def n_states_max(self) -> int:
        return self.pgm.log_psi_v.shape[2]

    def graph(self, i: int) -> PGM:
        """Extract graph ``i`` as a standalone (bucket-padded) PGM."""
        return jax.tree.map(lambda x: x[i], self.pgm)

    def folded(self, mesh=None, *, axis: str = "bp") -> PGM:
        """The bucket as one disjoint-union PGM with B*E edges, B*V
        vertices: graph ``b``'s vertex ``u`` becomes ``b*V + u``. Message
        updates on the union are bitwise those of the member graphs (no
        cross edges; per-vertex segments keep their edge order), so the
        whole bucket rides the unmodified single-graph update path -- one
        segment-sum, one Pallas launch -- with the batch axis folded into
        the edge axis.

        With ``mesh`` given (a 1-D ``jax.sharding.Mesh`` whose axis is
        ``axis``), the folded (B*E,) edge grid is sharding-constrained over
        the mesh and the small vertex tables replicated, so XLA lays the
        union out shard-ready for the ``"sharded"`` update backend
        (``repro.dist``). Per-graph E is a multiple of EDGE_PAD and reverse
        pairs sit at adjacent even indices, so any even per-shard split of
        B*E keeps reverse lookups shard-local."""
        p = self.pgm
        b, e, v = self.size, self.n_edges, self.n_vertices
        off_v = (jnp.arange(b, dtype=jnp.int32) * v)[:, None]
        off_e = (jnp.arange(b, dtype=jnp.int32) * e)[:, None]
        union = PGM(
            edge_src=(p.edge_src + off_v).reshape(-1),
            edge_dst=(p.edge_dst + off_v).reshape(-1),
            edge_rev=(p.edge_rev + off_e).reshape(-1),
            edge_mask=p.edge_mask.reshape(-1),
            log_psi_e=p.log_psi_e.reshape(b * e, *p.log_psi_e.shape[2:]),
            log_psi_v=p.log_psi_v.reshape(b * v, -1),
            state_mask=p.state_mask.reshape(b * v, -1),
            n_states=p.n_states.reshape(-1),
            n_real_vertices=b * v, n_real_edges=b * e,
            edge_count=jnp.int32(b * e), vertex_count=jnp.int32(b * v))
        if mesh is None:
            return union
        from jax.sharding import NamedSharding, PartitionSpec as P
        wsc = jax.lax.with_sharding_constraint
        shard = lambda x, spec: wsc(x, NamedSharding(mesh, spec))
        edge, rep = P(axis), P(None, None)
        return dataclasses.replace(
            union,
            edge_src=shard(union.edge_src, edge),
            edge_dst=shard(union.edge_dst, edge),
            edge_rev=shard(union.edge_rev, edge),
            edge_mask=shard(union.edge_mask, edge),
            log_psi_e=shard(union.log_psi_e, P(axis, None, None)),
            log_psi_v=shard(union.log_psi_v, rep),
            state_mask=shard(union.state_mask, rep),
            n_states=shard(union.n_states, P(None)))

    def take(self, indices) -> "BatchedPGM":
        """Narrow the batch to the given slot ``indices`` (gather along the
        batch axis) -- the compaction primitive. Static ceilings (treedef)
        are preserved, so the kept graphs' padded shapes -- and hence their
        trajectories -- are untouched; only the batch width changes (one
        recompile per new width)."""
        ia = jnp.asarray(indices, dtype=jnp.int32)
        return BatchedPGM(pgm=jax.tree.map(lambda x: x[ia], self.pgm))

    @classmethod
    def from_pgms(cls, pgms: Sequence[PGM], *,
                  n_edges: int | None = None,
                  n_vertices: int | None = None,
                  n_states: int | None = None,
                  n_real_edges: int | None = None,
                  n_real_vertices: int | None = None) -> "BatchedPGM":
        """Pad ``pgms`` to their joint max (E, V, S) shape -- or the given
        explicit ceilings -- and stack.

        Explicit ceilings let a rolling batch (engine evacuation/backfill)
        reserve the *group-wide* shape and static-metadata ceilings up
        front, so any graph of the group can later be loaded into any slot
        without changing the treedef or retracing.

        Padding + stacking run in numpy (one device transfer per field at
        the end): a fresh mixed-shape stream would otherwise trigger one
        tiny XLA compilation per (pad op, shape) pair -- seconds of hidden
        warm-up before the engine ever runs.
        """
        assert len(pgms) > 0, "empty batch"
        e_b = n_edges or max(p.n_edges for p in pgms)
        v_b = n_vertices or max(p.n_vertices for p in pgms)
        s_b = n_states or max(p.n_states_max for p in pgms)
        padded = [pad_pgm_arrays(p, n_edges=e_b, n_vertices=v_b,
                                 n_states=s_b) for p in pgms]
        stacked = {k: jnp.asarray(np.stack([d[k] for d in padded]))
                   for k in padded[0]}
        return cls(pgm=PGM(
            n_real_vertices=(n_real_vertices
                             or max(p.n_real_vertices for p in pgms)),
            n_real_edges=(n_real_edges
                          or max(p.n_real_edges for p in pgms)), **stacked))


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One shape-homogeneous batch plus the input positions it came from."""
    indices: Tuple[int, ...]
    batch: BatchedPGM


def bucket_key(pgm: PGM, growth: float = 2.0) -> tuple:
    """Bucket shape key: (growth-factor ceiling of the padded edge count,
    pow2-ceil state count). Graphs sharing a key share a padded bucket shape
    -- and, for the evacuating server, a backfill pool."""
    import math
    if not growth > 1.0:
        raise ValueError(f"growth must be > 1 (got {growth}); use 2.0 for "
                         "pow2 buckets or math.inf for a single bucket")
    e = _round_up(max(pgm.n_real_edges, 1), EDGE_PAD)
    if math.isinf(growth):
        ekey = 0
    elif growth == 2.0:
        ekey = _pow2_ceil(e)
    else:
        ekey = math.ceil(math.log(e, growth) - 1e-9)
    return (ekey, _pow2_ceil(pgm.n_states_max))


def bucket_shape(pgm: PGM, growth: float = 2.0) -> tuple[int, int, int,
                                                         int, int]:
    """Per-request deterministic padded-shape ceilings for *online*
    bucketing: ``(n_edges, n_vertices, n_states, n_real_edges,
    n_real_vertices)``.

    Unlike ``group_ceilings`` (the materialized-stream policy: joint max
    over a known group), these depend only on the request itself -- the
    edge axis takes its ``growth``-factor ceiling (as ``bucket_key``), the
    vertex and state axes their pow2 ceilings -- so an online server can
    pad, stage, and batch a request the moment it arrives, and any two
    requests with equal ceilings share a bucket. The static real-count
    ceilings are set to the padded ceilings (a valid upper bound; note
    size-derived scheduler constants like RBP's ``k = p * n_real_edges``
    then scale with the bucket, not the graph -- the same class of caveat
    as any re-padding). Requires finite ``growth``: ``inf`` has no
    per-request shape."""
    import math
    if not growth > 1.0 or math.isinf(growth):
        raise ValueError("online bucketing needs finite growth > 1, got "
                         f"{growth}")
    e = max(_round_up(max(pgm.n_real_edges, 1), EDGE_PAD), pgm.n_edges)
    if growth == 2.0:
        e_c = _pow2_ceil(e)
    else:
        k = math.ceil(math.log(e, growth) - 1e-9)
        e_c = max(_round_up(int(math.ceil(growth ** k)), EDGE_PAD), e)
    v_c = _pow2_ceil(max(_round_up(pgm.n_real_vertices + 1, VERTEX_PAD),
                         pgm.n_vertices))
    s_c = _pow2_ceil(pgm.n_states_max)
    return (e_c, v_c, s_c, e_c, v_c)


def group_ceilings(pgms: Sequence[PGM]) -> tuple[int, int, int, int, int]:
    """Joint padded-shape and static-metadata ceilings over a graph group:
    ``(n_edges, n_vertices, n_states, n_real_edges, n_real_vertices)``."""
    return (max(p.n_edges for p in pgms),
            max(p.n_vertices for p in pgms),
            max(p.n_states_max for p in pgms),
            max(p.n_real_edges for p in pgms),
            max(p.n_real_vertices for p in pgms))


def bucket_pgms(pgms: Sequence[PGM], *,
                growth: float = 2.0,
                max_batch: int | None = None) -> List[Bucket]:
    """Group heterogeneous graphs into padded, shape-homogeneous buckets.

    Bucket key = (growth-factor ceiling of the padded edge count, pow2-ceil
    state count): within a bucket no graph pays more than ~``growth``x
    padding on the edge axis (the dominant cost, ``log_psi_e`` is E*S^2) or
    ~2x on the state axis. The vertex axis simply takes the bucket max --
    V <= E for connected graphs, so it never dominates.

    ``growth`` is the compile-vs-compute policy knob: 2.0 (default) bounds
    padding waste at 2x per graph and suits steady-state traffic over few
    shape families; large values (or ``inf`` for one bucket) collapse a
    shape-diverse stream into few XLA compilations -- the dominant cost when
    serving cold traffic whose request shapes are effectively unbounded.
    ``max_batch`` caps graphs per bucket (VMEM/HBM guard).
    """
    keyed: dict[tuple, List[int]] = {}
    for i, p in enumerate(pgms):
        keyed.setdefault(bucket_key(p, growth), []).append(i)
    buckets = []
    for key in sorted(keyed):
        idx = keyed[key]
        chunks = ([idx] if not max_batch else
                  [idx[i:i + max_batch] for i in range(0, len(idx), max_batch)])
        for chunk in chunks:
            batch = BatchedPGM.from_pgms([pgms[i] for i in chunk])
            buckets.append(Bucket(indices=tuple(chunk), batch=batch))
    return buckets


class RidgeEffort:
    """Tiny incrementally-fit ridge regression predicting rounds-to-converge.

    The learned half of effort calibration: each completed request
    contributes one ``(features, rounds)`` observation via normal-equation
    accumulators (``A^T A`` / ``A^T y``, O(d^2) per fit, d = ``DIM``), and
    ``predict`` solves the l2-regularized system lazily. Features come from
    :meth:`features`: a bias, the admission score (residual-at-admit), the
    log-scaled edge/state ceilings mined from the kind tuple, and up to two
    caller-supplied extras (the deadline policy passes coupling-strength
    stats). Because size enters as a *feature* rather than a table key, one
    global model generalizes across kinds -- an unseen bucket shape gets a
    prediction from the first observation of any other shape, which the
    nearest-neighbor table it replaces never could.

    ``to_dict``/``from_dict`` round-trip the accumulators exactly (JSON-safe
    nested lists), so a warm effort model can ship with a deployment spec.
    Not internally locked: :class:`RoundsHistory` serializes access."""

    #: feature dimension: [1, score, log1p(edges), log1p(states), extra0,
    #: extra1]
    DIM = 6

    def __init__(self, l2: float = 1.0):
        if l2 <= 0:
            raise ValueError(f"l2 must be > 0, got {l2}")
        self.l2 = float(l2)
        self._ata = np.zeros((self.DIM, self.DIM), dtype=np.float64)
        self._aty = np.zeros(self.DIM, dtype=np.float64)
        self._n = 0
        self._w: np.ndarray | None = None

    @staticmethod
    def features(kind, score: float,
                 extra: Sequence[float] = ()) -> np.ndarray:
        """The fixed-width feature vector for one request: ``[1, score,
        log1p(edge ceiling), log1p(state ceiling), extra...]``, zero-padded
        to ``DIM``. Numeric leaves are mined from the (possibly nested)
        ``kind`` tuple -- serving kinds are ``bucket_shape`` ceilings
        ``(E, V, S, rE, rV)``, router kinds wrap them in ``("routed", ...)``
        -- with non-numeric leaves skipped, so any hashable kind works."""
        nums: List[float] = []

        def walk(x):
            if isinstance(x, bool):
                return
            if isinstance(x, (int, float, np.integer, np.floating)):
                nums.append(float(x))
            elif isinstance(x, (tuple, list)):
                for y in x:
                    walk(y)

        walk(kind)
        f = [1.0, float(score)]
        f += [float(np.log1p(abs(nums[i]))) for i in (0, 2)
              if i < len(nums)]                    # edge / state ceilings
        f += [float(v) for v in list(extra)[:RidgeEffort.DIM - len(f)]]
        f += [0.0] * (RidgeEffort.DIM - len(f))
        return np.asarray(f[:RidgeEffort.DIM], dtype=np.float64)

    @property
    def n_observations(self) -> int:
        """Observations fitted so far."""
        return self._n

    def fit_one(self, x: np.ndarray, y: float) -> None:
        """Accumulate one observation (features ``x``, observed rounds
        ``y``) into the normal equations; invalidates the cached solve."""
        x = np.asarray(x, dtype=np.float64)
        self._ata += np.outer(x, x)
        self._aty += float(y) * x
        self._n += 1
        self._w = None

    def predict(self, x: np.ndarray) -> float | None:
        """Predicted rounds for features ``x`` (clipped at 0; ``None``
        until at least two observations were fitted -- one point cannot
        anchor a slope)."""
        if self._n < 2:
            return None
        if self._w is None:
            self._w = np.linalg.solve(
                self._ata + self.l2 * np.eye(self.DIM), self._aty)
        return max(float(np.dot(x, self._w)), 0.0)

    def to_dict(self) -> dict:
        """JSON-ready accumulator state (exact round-trip)."""
        return {"l2": self.l2, "n": self._n,
                "ata": self._ata.tolist(), "aty": self._aty.tolist()}

    @classmethod
    def from_dict(cls, d: Mapping) -> "RidgeEffort":
        """Rebuild a model from :meth:`to_dict` output."""
        m = cls(l2=float(d["l2"]))
        m._n = int(d["n"])
        m._ata = np.asarray(d["ata"], dtype=np.float64)
        m._aty = np.asarray(d["aty"], dtype=np.float64)
        return m


class RoundsHistory:
    """Bounded, thread-safe effort calibration: per-kind observations plus
    (by default) a learned :class:`RidgeEffort` predictor over them.

    A *kind* is any hashable key naming a family of similar requests -- the
    serving layer uses the bucket-shape ceilings (``bucket_shape`` /
    ``group_ceilings`` tuples), so graphs that share a padded shape share a
    history. ``observe(kind, score, rounds)`` records one finished request's
    (admission score, rounds actually run); ``expect(kind, score)`` predicts
    the rounds a new request will need; ``mean(kind)`` is the score-free
    aggregate the router tier uses for effort-in-flight load estimates.

    ``predictor`` picks the expectation model: ``"ridge"`` (default) fits
    one incremental :class:`RidgeEffort` regression over (score, size, extra)
    features of *every* observation -- cross-kind generalization, so unseen
    shapes stop cold-starting -- while ``"nearest"`` is the original
    per-kind nearest-recorded-score lookup. Both fall back, in order, to
    the kind's nearest observation, the constructor ``prior`` (the
    prior-seeding knob: a deployment's known typical rounds), and finally
    the caller's ``default=`` -- so callers no longer need a ``None``
    branch. ``capacity`` bounds observations kept per kind (a deque, so
    drifting workloads age out), keeping host memory O(kinds) on
    indefinitely long streams.

    This is the feedback half of Residual-BP-style admission
    (``repro.core.serving.ResidualAdmission`` and the ``deadline`` policy's
    slack prediction): the cheap residual-at-admit proxy orders requests,
    and this history calibrates that proxy into expected effort from what
    actually happened to similar requests.

    All methods lock, so one instance may be shared across serving threads
    -- ``repro.serve`` hands every replica the same history, pooling effort
    calibration instead of cold-starting it per replica."""

    def __init__(self, capacity: int = 64, *, predictor: str = "ridge",
                 prior: float | None = None, l2: float = 1.0):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if predictor not in ("ridge", "nearest"):
            raise ValueError(
                f"predictor must be 'ridge' or 'nearest', got {predictor!r}")
        self.capacity = capacity
        self.predictor = predictor
        self.prior = None if prior is None else float(prior)
        self._model = RidgeEffort(l2=l2) if predictor == "ridge" else None
        self._hist: Dict[Any, Deque[Tuple[float, float]]] = {}
        self._lock = threading.Lock()

    def observe(self, kind, score: float, rounds: float,
                extra: Sequence[float] = ()) -> None:
        """Record one completed request of ``kind``: its admission score,
        the rounds it actually ran before release, and optional extra
        feature values (coupling stats) for the learned predictor."""
        with self._lock:
            dq = self._hist.get(kind)
            if dq is None:
                dq = self._hist[kind] = deque(maxlen=self.capacity)
            dq.append((float(score), float(rounds)))
            if self._model is not None:
                self._model.fit_one(
                    RidgeEffort.features(kind, score, extra), rounds)

    def _nearest(self, kind, score: float) -> float | None:
        dq = self._hist.get(kind)
        if not dq:
            return None
        return min(dq, key=lambda sr: abs(sr[0] - float(score)))[1]

    def expect(self, kind, score: float, *, default: float | None = None,
               extra: Sequence[float] = ()) -> float | None:
        """Expected rounds for a new request of ``kind`` with admission
        ``score``: the ridge prediction when the model has data (any kind's
        data -- size is a feature), else the kind's nearest recorded score,
        else the seeded ``prior``, else ``default``. Callers that always
        need a number pass ``default=`` instead of branching on ``None``."""
        with self._lock:
            if self._model is not None:
                est = self._model.predict(
                    RidgeEffort.features(kind, score, extra))
                if est is not None:
                    return est
            est = self._nearest(kind, score)
            if est is not None:
                return est
            return self.prior if self.prior is not None else default

    def mean(self, kind=None, *, default: float | None = None
             ) -> float | None:
        """Mean observed rounds across every record of ``kind`` -- the
        score-free effort estimate for callers with no admission score at
        hand (request routing). An unseen kind falls back to the global
        mean over *all* kinds (``kind=None`` asks for that directly), then
        the seeded ``prior``, then ``default``."""
        with self._lock:
            if kind is not None:
                dq = self._hist.get(kind)
                if dq:
                    return sum(r for _, r in dq) / len(dq)
            total = n = 0.0
            for dq in self._hist.values():
                total += sum(r for _, r in dq)
                n += len(dq)
            if n:
                return total / n
            return self.prior if self.prior is not None else default

    def to_dict(self) -> dict:
        """JSON-ready snapshot: config, per-kind observations (kinds keyed
        by ``repr``), and the ridge accumulators. Round-trips through
        :meth:`from_dict` to a history with identical predictions."""
        with self._lock:
            return {
                "capacity": self.capacity, "predictor": self.predictor,
                "prior": self.prior,
                "model": None if self._model is None
                else self._model.to_dict(),
                "hist": [[repr(k), [list(sr) for sr in dq]]
                         for k, dq in self._hist.items()],
            }

    @classmethod
    def from_dict(cls, d: Mapping) -> "RoundsHistory":
        """Rebuild a history from :meth:`to_dict` output. Kind keys were
        serialized by ``repr`` and are restored via ``ast.literal_eval``
        (serving kinds are literal tuples); non-literal kinds keep their
        repr string as the key -- predictions still work, size features
        simply read as absent."""
        import ast
        h = cls(capacity=int(d["capacity"]), predictor=d["predictor"],
                prior=d.get("prior"))
        if d.get("model") is not None:
            h._model = RidgeEffort.from_dict(d["model"])
        for krepr, obs in d.get("hist", ()):
            try:
                kind = ast.literal_eval(krepr)
            except (ValueError, SyntaxError):
                kind = krepr
            dq = deque(maxlen=h.capacity)
            dq.extend((float(s), float(r)) for s, r in obs)
            h._hist[kind] = dq
        return h

    def __len__(self) -> int:
        with self._lock:
            return sum(len(dq) for dq in self._hist.values())


def batch_keys(rng: jax.Array, batch: BatchedPGM | int) -> jax.Array:
    """(B,) per-graph RNG keys from one base key (or pass-through if the
    caller already supplies a (B,) key array)."""
    b = batch if isinstance(batch, int) else batch.size
    if rng.ndim == 1 and rng.shape[0] == b and jnp.issubdtype(
            rng.dtype, jax.dtypes.prng_key):
        return rng
    return jax.random.split(rng, b)


def _deprecated(name: str) -> None:
    warnings.warn(
        f"{name} is deprecated: use repro.core.BPEngine with a BPConfig "
        "(config-driven scheduler/backend, chunked resume, evacuation)",
        DeprecationWarning, stacklevel=3)


def run_bp_batch(batch: BatchedPGM,
                 scheduler: Scheduler,
                 rng: jax.Array,
                 *,
                 eps: float = 1e-3,
                 max_rounds: int = 2000,
                 damping: float = 0.0,
                 update_fn: Callable | None = None,
                 batch_update_fn: Callable | None = None,
                 track_history: bool = False):
    """Deprecated wrapper: ``BPEngine(BPConfig(...)).run(batch, rng)``.

    Exact-trajectory parity with the historic one-``while_loop``
    implementation (the engine runs the same gated body); returns a
    ``BPResult`` whose every field carries a leading batch axis, each slice
    equal to the graph's solo ``run_bp`` trajectory.
    """
    from repro.core.engine import BPConfig, BPEngine
    _deprecated("run_bp_batch")
    cfg = BPConfig(scheduler=scheduler, eps=eps, max_rounds=max_rounds,
                   damping=damping,
                   backend=update_fn if update_fn is not None else "ref",
                   batch_backend=batch_update_fn, history=track_history)
    return BPEngine(cfg).run(batch, rng)


def run_bp_many(pgms: Sequence[PGM],
                scheduler: Scheduler,
                rng: jax.Array,
                *,
                growth: float = 2.0,
                max_batch: int | None = None,
                **bp_kwargs: Any):
    """Deprecated wrapper: ``BPEngine(BPConfig(...)).run_many(pgms, rng)``
    (or ``.serve(...)`` for the evacuating path). Per-graph keys are
    ``fold_in(rng, input position)``, independent of bucketing."""
    from repro.core.engine import BPConfig, BPEngine
    _deprecated("run_bp_many")
    cfg = BPConfig(scheduler=scheduler,
                   eps=bp_kwargs.pop("eps", 1e-3),
                   max_rounds=bp_kwargs.pop("max_rounds", 2000),
                   damping=bp_kwargs.pop("damping", 0.0),
                   backend=bp_kwargs.pop("update_fn", None) or "ref",
                   batch_backend=bp_kwargs.pop("batch_update_fn", None),
                   history=bp_kwargs.pop("track_history", False))
    if bp_kwargs:
        raise TypeError(f"unknown arguments: {sorted(bp_kwargs)}")
    return BPEngine(cfg).run_many(pgms, rng, growth=growth,
                                  max_batch=max_batch)
