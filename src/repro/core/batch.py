"""Batched multi-graph BP engine: vmap-able PGM buckets + padded batches.

A single sparse PGM rarely saturates a many-core device; the serving
workload is *many independent* inference problems per device step. This
module provides the batching primitive every scaling layer builds on:

- ``BatchedPGM``: B same-shape graphs stacked leaf-wise. The element ``PGM``
  keeps bucket-ceiling *static* metadata (shared treedef / one compilation)
  while per-graph real sizes ride along as traced ``(B,)`` scalars, which the
  schedulers consume via ``traced_edge_count``/``traced_vertex_count``.
- ``bucket_pgms``: groups heterogeneous graphs into buckets keyed by
  power-of-two (edge, state) ceilings, bounding padding waste at ~2x per
  axis, then pads each graph to its bucket shape with ``pad_pgm``.
- ``run_bp_batch``: one ``lax.while_loop`` over the whole batch. The body is
  the exact per-slice body of ``repro.core.runner.run_bp`` (scheduler
  ``init``/``select`` and the frontier commit are ``jax.vmap``-ed), so a
  batched graph reproduces its solo ``run_bp`` trajectory bit-for-trace:
  converged graphs keep executing an idempotent body (frontier zeroed,
  rounds frozen) until the whole bucket finishes. The message update runs
  on the *disjoint union* of the bucket -- ``BatchedPGM.folded()`` offsets
  vertex/edge ids so B graphs become one (B*E)-edge graph -- which both
  beats a ``vmap``-ed update (one flat segment-sum instead of a batched
  scatter) and reuses the unmodified single-graph ``update_fn``, Pallas
  kernel included: the batch axis simply disappears into the kernel's edge
  grid. ``batch_update_fn`` remains as an escape hatch for natively batched
  updates (``repro.kernels.ops.make_pallas_update_batch``).
- ``run_bp_many``: the serving entry point -- bucket a heterogeneous graph
  list, run each bucket batched, scatter per-graph results back to input
  order.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import messages as M
from repro.core.graph import EDGE_PAD, PGM, pad_pgm_arrays
from repro.core.runner import BPResult
from repro.core.schedulers.base import Scheduler


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pow2_ceil(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BatchedPGM:
    """B graphs padded to one (E, V, S) bucket shape, stacked leaf-wise.

    ``pgm`` is an element-``PGM`` whose array leaves carry a leading batch
    axis -- ``edge_src (B, E)``, ``log_psi_e (B, E, S, S)``, ... -- and whose
    static ints are the bucket ceilings. Slicing out ``graph(i)`` yields a
    standalone ``PGM`` that runs through plain ``run_bp`` and reproduces the
    batched trajectory of graph ``i`` exactly.
    """

    pgm: PGM

    @property
    def size(self) -> int:
        return self.pgm.edge_src.shape[0]

    @property
    def n_edges(self) -> int:
        return self.pgm.edge_src.shape[1]

    @property
    def n_vertices(self) -> int:
        return self.pgm.log_psi_v.shape[1]

    @property
    def n_states_max(self) -> int:
        return self.pgm.log_psi_v.shape[2]

    def graph(self, i: int) -> PGM:
        """Extract graph ``i`` as a standalone (bucket-padded) PGM."""
        return jax.tree.map(lambda x: x[i], self.pgm)

    def folded(self) -> PGM:
        """The bucket as one disjoint-union PGM with B*E edges, B*V
        vertices: graph ``b``'s vertex ``u`` becomes ``b*V + u``. Message
        updates on the union are bitwise those of the member graphs (no
        cross edges; per-vertex segments keep their edge order), so the
        whole bucket rides the unmodified single-graph update path -- one
        segment-sum, one Pallas launch -- with the batch axis folded into
        the edge axis."""
        p = self.pgm
        b, e, v = self.size, self.n_edges, self.n_vertices
        off_v = (jnp.arange(b, dtype=jnp.int32) * v)[:, None]
        off_e = (jnp.arange(b, dtype=jnp.int32) * e)[:, None]
        return PGM(
            edge_src=(p.edge_src + off_v).reshape(-1),
            edge_dst=(p.edge_dst + off_v).reshape(-1),
            edge_rev=(p.edge_rev + off_e).reshape(-1),
            edge_mask=p.edge_mask.reshape(-1),
            log_psi_e=p.log_psi_e.reshape(b * e, *p.log_psi_e.shape[2:]),
            log_psi_v=p.log_psi_v.reshape(b * v, -1),
            state_mask=p.state_mask.reshape(b * v, -1),
            n_states=p.n_states.reshape(-1),
            n_real_vertices=b * v, n_real_edges=b * e,
            edge_count=jnp.int32(b * e), vertex_count=jnp.int32(b * v))

    @classmethod
    def from_pgms(cls, pgms: Sequence[PGM]) -> "BatchedPGM":
        """Pad ``pgms`` to their joint max (E, V, S) shape and stack.

        Padding + stacking run in numpy (one device transfer per field at
        the end): a fresh mixed-shape stream would otherwise trigger one
        tiny XLA compilation per (pad op, shape) pair -- seconds of hidden
        warm-up before the engine ever runs.
        """
        assert len(pgms) > 0, "empty batch"
        e_b = max(p.n_edges for p in pgms)
        v_b = max(p.n_vertices for p in pgms)
        s_b = max(p.n_states_max for p in pgms)
        padded = [pad_pgm_arrays(p, n_edges=e_b, n_vertices=v_b,
                                 n_states=s_b) for p in pgms]
        stacked = {k: jnp.asarray(np.stack([d[k] for d in padded]))
                   for k in padded[0]}
        return cls(pgm=PGM(
            n_real_vertices=max(p.n_real_vertices for p in pgms),
            n_real_edges=max(p.n_real_edges for p in pgms), **stacked))


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One shape-homogeneous batch plus the input positions it came from."""
    indices: Tuple[int, ...]
    batch: BatchedPGM


def bucket_pgms(pgms: Sequence[PGM], *,
                growth: float = 2.0,
                max_batch: int | None = None) -> List[Bucket]:
    """Group heterogeneous graphs into padded, shape-homogeneous buckets.

    Bucket key = (growth-factor ceiling of the padded edge count, pow2-ceil
    state count): within a bucket no graph pays more than ~``growth``x
    padding on the edge axis (the dominant cost, ``log_psi_e`` is E*S^2) or
    ~2x on the state axis. The vertex axis simply takes the bucket max --
    V <= E for connected graphs, so it never dominates.

    ``growth`` is the compile-vs-compute policy knob: 2.0 (default) bounds
    padding waste at 2x per graph and suits steady-state traffic over few
    shape families; large values (or ``inf`` for one bucket) collapse a
    shape-diverse stream into few XLA compilations -- the dominant cost when
    serving cold traffic whose request shapes are effectively unbounded.
    ``max_batch`` caps graphs per bucket (VMEM/HBM guard).
    """
    import math
    if not growth > 1.0:
        raise ValueError(f"growth must be > 1 (got {growth}); use 2.0 for "
                         "pow2 buckets or math.inf for a single bucket")
    keyed: dict[tuple, List[int]] = {}
    for i, p in enumerate(pgms):
        e = _round_up(max(p.n_real_edges, 1), EDGE_PAD)
        if math.isinf(growth):
            ekey = 0
        elif growth == 2.0:
            ekey = _pow2_ceil(e)
        else:
            ekey = math.ceil(math.log(e, growth) - 1e-9)
        key = (ekey, _pow2_ceil(p.n_states_max))
        keyed.setdefault(key, []).append(i)
    buckets = []
    for key in sorted(keyed):
        idx = keyed[key]
        chunks = ([idx] if not max_batch else
                  [idx[i:i + max_batch] for i in range(0, len(idx), max_batch)])
        for chunk in chunks:
            batch = BatchedPGM.from_pgms([pgms[i] for i in chunk])
            buckets.append(Bucket(indices=tuple(chunk), batch=batch))
    return buckets


def batch_keys(rng: jax.Array, batch: BatchedPGM | int) -> jax.Array:
    """(B,) per-graph RNG keys from one base key (or pass-through if the
    caller already supplies a (B,) key array)."""
    b = batch if isinstance(batch, int) else batch.size
    if rng.ndim == 1 and rng.shape[0] == b and jnp.issubdtype(
            rng.dtype, jax.dtypes.prng_key):
        return rng
    return jax.random.split(rng, b)


@partial(jax.jit, static_argnames=("scheduler", "max_rounds", "damping",
                                   "update_fn", "batch_update_fn",
                                   "track_history"))
def run_bp_batch(batch: BatchedPGM,
                 scheduler: Scheduler,
                 rng: jax.Array,
                 *,
                 eps: float = 1e-3,
                 max_rounds: int = 2000,
                 damping: float = 0.0,
                 update_fn: Callable = M.ref_update,
                 batch_update_fn: Callable | None = None,
                 track_history: bool = False) -> BPResult:
    """Frontier-based BP over a whole bucket in one ``lax.while_loop``.

    Returns a ``BPResult`` whose every field carries a leading batch axis
    (``beliefs (B, V, S)``, ``rounds (B,)``, ``converged (B,)``, ...).
    Per-graph convergence is exact: a converged graph's body becomes a no-op
    (frontier zeroed, rounds/updates frozen) while stragglers finish, so
    each slice equals ``run_bp(batch.graph(i), scheduler, keys[i], ...)``.

    ``rng`` is either one base key (split into per-graph keys) or a ``(B,)``
    key array. ``update_fn`` is the single-graph update (reference or
    ``make_pallas_update``); it runs once per round on the bucket's
    disjoint-union fold, covering all B graphs in one pass / one kernel
    launch. ``batch_update_fn`` overrides it with a natively batched update
    on the full ``(B, E, S)`` block.
    """
    bpgm = batch.pgm
    b, e = batch.size, batch.n_edges
    s = batch.n_states_max
    keys0 = batch_keys(rng, b)
    if batch_update_fn is None:
        union = batch.folded()

        def batch_update_fn(_, logm):
            cand, r = update_fn(union, logm.reshape(b * e, s))
            return cand.reshape(b, e, s), r.reshape(b, e)

    logm0 = jax.vmap(M.init_messages)(bpgm)                    # (B, E, S)
    hist0 = jnp.full((b, max_rounds if track_history else 1), -1, jnp.int32)
    select = jax.vmap(
        lambda p, r, k, s, u: scheduler.select(p, r, eps, k, s, u))
    commit = jax.vmap(partial(M.apply_frontier, damping=damping))

    def cond(carry):
        _, _, _, rounds, done, _, _, _ = carry
        return jnp.any((~done) & (rounds < max_rounds))

    def body(carry):
        logm, sstate, keys, rounds, done, updates, hist, _ = carry
        split = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
        keys, sel_keys = split[:, 0], split[:, 1]
        cand, r = batch_update_fn(bpgm, logm)
        unconverged = jnp.sum((r >= eps) & bpgm.edge_mask,
                              axis=1).astype(jnp.int32)        # (B,)
        frontier, sstate = select(bpgm, r, sel_keys, sstate, unconverged)
        newly_done = unconverged == 0
        frontier = frontier & ~newly_done[:, None]
        logm = commit(logm, cand, frontier)
        for _ in range(scheduler.inner_sweeps - 1):
            cand, _ = batch_update_fn(bpgm, logm)
            logm = commit(logm, cand, frontier)
        updates = updates + jnp.sum(frontier, axis=1).astype(jnp.float32) \
            * scheduler.inner_sweeps
        if track_history:
            hist = jax.vmap(lambda h, i, u: h.at[i].set(u))(
                hist, rounds, unconverged)
        rounds = rounds + jnp.where(newly_done, 0,
                                    jnp.int32(scheduler.inner_sweeps))
        max_r = jnp.max(r, axis=1)
        return (logm, sstate, keys, rounds, newly_done, updates, hist, max_r)

    sstate0 = jax.vmap(scheduler.init)(bpgm)
    carry0 = (logm0, sstate0, keys0, jnp.zeros((b,), jnp.int32),
              jnp.zeros((b,), bool), jnp.zeros((b,), jnp.float32), hist0,
              jnp.full((b,), jnp.inf, jnp.float32))
    logm, sstate, _, rounds, done, updates, hist, max_r = jax.lax.while_loop(
        cond, body, carry0)
    return BPResult(beliefs=jax.vmap(M.beliefs)(bpgm, logm), logm=logm,
                    rounds=rounds, updates=updates, converged=done,
                    max_residual=max_r, unconverged_history=hist,
                    sched_state=sstate)


def run_bp_many(pgms: Sequence[PGM],
                scheduler: Scheduler,
                rng: jax.Array,
                *,
                growth: float = 2.0,
                max_batch: int | None = None,
                **bp_kwargs: Any) -> List[BPResult]:
    """Bucket ``pgms``, run each bucket through ``run_bp_batch``, and return
    per-graph results in input order. Per-graph keys are ``fold_in(rng, i)``
    over the *input* position, so results are independent of bucketing.
    """
    results: List[BPResult | None] = [None] * len(pgms)
    for bucket in bucket_pgms(pgms, growth=growth, max_batch=max_batch):
        keys = jnp.stack([jax.random.fold_in(rng, i)
                          for i in bucket.indices])
        res = run_bp_batch(bucket.batch, scheduler, keys, **bp_kwargs)
        for j, gi in enumerate(bucket.indices):
            results[gi] = jax.tree.map(lambda x: x[j], res)
    return results  # type: ignore[return-value]
