"""Asynchronous BP serving: online request streams, double-buffered bucket
slots, prefetch staging, and bucket compaction.

``BPEngine.serve`` (repro.core.engine) made the engine a scheduler one level
up: it decides which graphs occupy device slots each chunk. But the legacy
driver materializes the whole request list, steps one resident bucket at a
time, and keeps a bucket at its admission width until its group finishes --
once the pending queue drains, evacuated slots are dead weight every
remaining chunk still pays for. This module rebuilds that loop as a
pipeline:

- **online streams**: requests arrive from any iterator; nothing needs the
  full workload up front. Arrivals are *staged* -- padded host-side (numpy,
  no XLA warm-up) and moved early with ``jax.device_put`` -- so admission
  and backfill never wait on host prep or H2D transfer.
- **double-buffered slots**: up to ``slots`` resident buckets are stepped
  per cycle. Every slot dispatches first (JAX async dispatch returns
  before the chunk finishes), then the host pulls and stages new arrivals
  *while the device crunches*, and only then does each slot sync and get
  serviced (evacuation, backfill, compaction). Host bucketing no longer
  idles the device, and a straggling bucket no longer idles the host.
- **bucket compaction**: when a group's queue has drained and the stream is
  exhausted, survivors re-bucket into a narrower batch (power-of-two
  widths, so at most log2(width) recompiles per shape family), removing
  the dead-slot sweeps that evacuation alone cannot -- a slot with no
  pending work to backfill still costs one device sweep per loop iteration
  at the old width.
- **admission policies**: *which staged request enters a bucket when* is a
  pluggable :class:`AdmissionPolicy` resolved through the
  ``ADMISSION_POLICIES`` registry (mirroring the scheduler and update-
  backend registries): ``"fifo"`` is the arrival-order default (bitwise the
  pre-policy behavior), ``"residual"`` lifts Residual BP's
  prioritize-by-expected-effort argument from message scheduling to request
  admission (a cheap residual-at-admit score, calibrated by per-kind
  observed-rounds history, co-batches similar-effort requests so stragglers
  stop pinning buckets of fast peers), ``"windowed"`` trades a small
  admission delay for fuller buckets (the p50-latency vs throughput knob),
  and ``"deadline"`` is the SLA tier: per-request latency budgets from the
  stream (``(rid, pgm, slo_s)`` triples), admission ordered by predicted
  slack, multiple groups packed into free slots per cycle (``pick_many``),
  and mid-flight eviction of requests whose residual decay says they will
  not make their deadline -- evicted requests surface as
  ``status="evicted"`` records with partial beliefs, never silently
  dropped. See ``docs/admission.md``.
- **threaded ingestion**: ``ingest_threads=N`` moves the stream pull onto
  feeder threads behind a bounded queue, so a source that blocks in
  ``__next__`` (a socket, a slow producer) no longer stalls device
  dispatch -- the serving loop keeps stepping resident buckets and drains
  the feeder opportunistically.

Trajectory invariance is the load-bearing property: a graph's trajectory
depends only on its own padded shape and RNG key (the batched loop body is
per-graph gated, and the update runs on a disjoint union), so neither the
slot count, nor backfill order, nor admission policy, nor compaction
changes any result bit. On a materialized ``Sequence`` the pipeline reuses
``serve``'s group-ceiling padding, making ``serve_async`` bitwise-identical
to the legacy driver -- which is now itself a thin wrapper over this
module.
"""

from __future__ import annotations

import dataclasses
import heapq
import queue as _queue
import threading
import time
from collections import deque
from typing import (Deque, Dict, Iterable, Iterator, List, Mapping, Sequence,
                    Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batch import (BatchedPGM, RoundsHistory, _pow2_ceil,
                              bucket_key, bucket_shape, group_ceilings)
from repro.core.engine import (BPEngine, BPResult, BPState, ServeStats,
                               _load_slot)
from repro.core.graph import NEG_INF, PGM, pad_pgm_arrays
from repro.core.registry import Registry

__all__ = ["ADMISSION_POLICIES", "AdmissionPolicy", "AsyncServeResult",
           "AsyncServeStats", "DeadlineAdmission", "FIFOAdmission",
           "RequestRecord", "ResidualAdmission", "ServingPipeline",
           "SweepClock", "WindowedAdmission", "get_admission_policy",
           "list_admission_policies", "register_admission_policy",
           "serve_async"]


# --------------------------------------------------------------- records --

@dataclasses.dataclass
class RequestRecord:
    """One served request: its ``BPResult`` plus the host-side timeline.

    ``t_enqueue`` is when the request was pulled from the stream (queue-in),
    ``t_admit`` when it was loaded into a resident bucket slot, ``t_done``
    when its result was released after a chunk sync (pipeline-clock
    seconds, ``perf_counter`` by default; the result's arrays may still be
    materializing -- release is dispatch, not blocking). ``latency_s`` is
    the serving metric: queue-in to result release.

    ``status`` is ``"completed"`` for the normal release path and
    ``"evicted"`` when the admission policy gave up on the request (the
    ``deadline`` policy's hopeless-work call); an evicted record still
    carries the request's *partial* result -- beliefs at the messages it
    reached, ``converged=False`` -- never a silent drop. A request evicted
    before it ever entered a bucket has ``t_admit == t_done`` (zero
    service time) and prior beliefs. ``slo_s`` is the request's latency
    budget from the stream (``None`` = no deadline)."""

    rid: int                    # input position (also the RNG fold_in index)
    result: BPResult
    t_enqueue: float
    t_admit: float
    t_done: float
    slo_s: float | None = None
    status: str = "completed"

    @property
    def latency_s(self) -> float:
        """Queue-in -> result-release latency, seconds."""
        return self.t_done - self.t_enqueue

    @property
    def evicted(self) -> bool:
        """True when the policy gave up on this request before it finished
        (``status == "evicted"``); the result is partial."""
        return self.status == "evicted"

    @property
    def deadline(self) -> float | None:
        """Absolute completion deadline in pipeline-clock seconds
        (``t_enqueue + slo_s``), or ``None`` without an SLO."""
        return None if self.slo_s is None else self.t_enqueue + self.slo_s

    @property
    def within_slo(self) -> bool:
        """Did this request complete within its latency budget? Requests
        without an SLO count as within; evicted ones never do."""
        if self.status != "completed":
            return False
        return self.slo_s is None or self.latency_s <= self.slo_s

    @property
    def queue_s(self) -> float:
        """Time spent waiting for a bucket slot, seconds."""
        return self.t_admit - self.t_enqueue

    @property
    def service_s(self) -> float:
        """Time resident in a bucket slot, seconds."""
        return self.t_done - self.t_admit


@dataclasses.dataclass
class AsyncServeStats(ServeStats):
    """``ServeStats`` plus the async pipeline's own accounting.

    ``compactions`` counts re-bucketing events (``compaction_log`` records
    ``(chunk index, width before, width after)`` for each);
    ``buckets_opened`` counts slot admissions (fresh resident batches, i.e.
    compile-relevant shapes seen), and ``staged`` counts requests pulled
    from the stream and prefetched to the device. ``policy`` names the
    admission policy that drove the run; ``admission_holds`` counts
    admission checks the policy deferred (a ``windowed`` policy holding a
    bucket open to fill it); ``admission_widths`` logs the width of every
    opened bucket (suppressed by ``record_events=False``), the direct
    observable for the latency-vs-fullness tradeoff.

    Eviction accounting (the ``deadline`` policy's hopeless-work calls):
    ``evictions`` counts requests released with ``status="evicted"``
    (mid-flight *and* expired-while-staged), ``evicted_sweeps`` the device
    sweeps those requests had consumed when given up on (a subset of
    ``useful_sweeps`` -- work that ran but missed its SLO), and
    ``eviction_log`` records ``(chunk index, rid)`` per event."""

    compactions: int = 0
    #: (chunk index, width before, width after) per compaction event
    compaction_log: List[Tuple[int, int, int]] = dataclasses.field(
        default_factory=list)
    buckets_opened: int = 0
    staged: int = 0
    policy: str = "fifo"
    admission_holds: int = 0
    #: width of each opened bucket, in admission order
    admission_widths: List[int] = dataclasses.field(default_factory=list)
    evictions: int = 0
    evicted_sweeps: int = 0
    #: (chunk index, rid) per eviction event
    eviction_log: List[Tuple[int, int]] = dataclasses.field(
        default_factory=list)


@dataclasses.dataclass
class AsyncServeResult:
    """``serve_async`` output: per-request records in *completion* order
    plus pipeline stats. ``results`` re-sorts to input (rid) order, matching
    the legacy ``ServeResult.results`` contract."""

    records: List[RequestRecord]    # completion order
    stats: AsyncServeStats

    @property
    def results(self) -> List[BPResult]:
        """Per-request ``BPResult`` list indexed by rid. For the usual
        dense 0..n-1 rids this is input order; streams that supplied sparse
        explicit rids leave ``None`` gaps at the unused positions (rejected
        beyond a small sparsity factor -- use ``.records`` there)."""
        n = 1 + max((rec.rid for rec in self.records), default=-1)
        if n > 4 * len(self.records) + 64:
            raise ValueError(
                f"rids too sparse for a dense results list (max rid {n - 1} "
                f"over {len(self.records)} records); use .records instead")
        out: List[BPResult | None] = [None] * n
        for rec in self.records:
            out[rec.rid] = rec.result
        return out  # type: ignore[return-value]

    def latency_percentiles(
            self, qs: Sequence[float] = (50, 95, 99), *,
            field: str = "latency",
            status: str | None = None) -> Dict[str, float]:
        """Latency percentiles in ms, ``{"p50": ...}`` (NaN entries when no
        matching requests were served). ``field`` selects the timeline
        component so admission wait and device residency report separately
        instead of conflated into one number: ``"latency"`` (queue-in ->
        result, the end-to-end metric), ``"admission"`` (queue-in -> admit,
        the wait the admission *policy* controls -- ``windowed`` trades it
        up, a hot backfill path trades it down), or ``"service"`` (admit ->
        result, the device-side residency time). ``status`` filters the
        records: ``"completed"`` / ``"evicted"`` / ``None`` (all). Once a
        run evicts, the unfiltered number conflates a fast eviction with a
        fast completion -- SLO reporting wants ``status="completed"``."""
        attrs = {"latency": "latency_s", "admission": "queue_s",
                 "service": "service_s"}
        if field not in attrs:
            raise KeyError(f"field must be one of {sorted(attrs)}, "
                           f"got {field!r}")
        if status not in (None, "completed", "evicted"):
            raise ValueError("status must be None, 'completed' or 'evicted',"
                             f" got {status!r}")
        recs = self.records if status is None else \
            [r for r in self.records if r.status == status]
        if not recs:
            return {f"p{q:g}": float("nan") for q in qs}
        lat = np.array([getattr(r, attrs[field]) for r in recs]) * 1e3
        return {f"p{q:g}": float(np.percentile(lat, q)) for q in qs}


# ------------------------------------------------------------- internals --

@dataclasses.dataclass
class _Staged:
    """A request staged for admission: padded to its group's ceilings and
    already ``device_put`` (the prefetch). ``score`` is the admission
    policy's effort estimate (0.0 under FIFO); ``passed_over`` counts takes
    that skipped this request while it was the queue head (the residual
    policy's aging/no-starvation counter); ``slo`` is the latency budget
    the stream attached (seconds from ``t_enqueue``, ``None`` = no
    deadline) and ``extra`` the policy's per-request feature tuple
    (coupling stats, fed to the learned effort model)."""
    rid: int
    elem: PGM
    key: jax.Array
    t_enqueue: float
    score: float = 0.0
    passed_over: int = 0
    slo: float | None = None
    extra: Tuple[float, ...] = ()


class _Group:
    """One shape family: fixed padded-shape ceilings + its pending queue
    (enqueue order; policies may remove from the middle, so the head is
    always the oldest *remaining* request)."""

    __slots__ = ("ceilings", "queue")

    def __init__(self, ceilings: Tuple[int, int, int, int, int]):
        self.ceilings = ceilings
        self.queue: Deque[_Staged] = deque()


@dataclasses.dataclass
class _AdmitMeta:
    """Host-side per-request metadata carried while resident in a slot."""
    t_enqueue: float
    t_admit: float
    score: float
    slo: float | None = None
    extra: Tuple[float, ...] = ()


@dataclasses.dataclass(eq=False)     # remove-by-identity from the slot list
class _Slot:
    """One resident bucket: its group, engine state, and host-side caches
    (live rid per batch slot, last-synced per-graph rounds, admit times)."""
    group: _Group
    state: BPState
    live: List[int | None]
    rounds_host: np.ndarray
    r_before: np.ndarray
    #: rid -> admit-time metadata (enqueue/admit times, score, slo)
    meta: Dict[int, _AdmitMeta]

    @property
    def width(self) -> int:
        return len(self.live)


def _narrow_state(state: BPState, idx: Sequence[int]) -> BPState:
    """Gather batch slots ``idx`` out of a batched ``BPState`` (the
    compaction primitive): every per-graph leaf -- graph arrays, messages,
    scheduler carry, RNG keys, counters -- is sliced along the batch axis,
    so each kept graph's trajectory continues bit-for-bit in the narrower
    batch."""
    ia = jnp.asarray(list(idx), dtype=jnp.int32)
    take = lambda x: x[ia]                                    # noqa: E731
    return dataclasses.replace(
        state,
        graph=state.graph.take(ia),
        logm=take(state.logm),
        sched_state=jax.tree.map(take, state.sched_state),
        rng=state.rng[ia],
        rounds=take(state.rounds),
        done=take(state.done),
        updates=take(state.updates),
        unconverged_history=take(state.unconverged_history),
        max_residual=take(state.max_residual))


# ----------------------------------------------------- admission policies --

def _residual_at_admit(arrs: Mapping[str, np.ndarray]) -> float:
    """Max L-inf residual of one BP step from uniform messages, computed
    host-side in numpy over the padded arrays ``pad_pgm_arrays`` produced.

    This is the paper's residual r(m) (Eq. 4) evaluated at the initial
    message state -- the same quantity Residual BP prioritizes *messages*
    by, here evaluated once per *request* as its admission score. Numpy on
    purpose: scoring happens at staging time on the serving/feeder thread,
    and a jnp pass would pay one XLA compilation per fresh shape (the exact
    warm-up the numpy staging path exists to avoid)."""
    emask = np.asarray(arrs["edge_mask"])                      # (E,)
    smask = np.asarray(arrs["state_mask"])                     # (V, S)
    dst = np.asarray(arrs["edge_dst"])
    src = np.asarray(arrs["edge_src"])
    n_states = np.asarray(arrs["n_states"]).astype(np.float64)
    dst_mask = smask[dst]                                      # (E, S)
    logm = np.where(dst_mask, -np.log(n_states[dst])[:, None], NEG_INF)
    contrib = np.where(emask[:, None], logm, 0.0)
    vsum = np.zeros_like(smask, dtype=np.float64)
    np.add.at(vsum, dst, contrib)
    pre = (np.asarray(arrs["log_psi_v"]) + vsum)[src] \
        - logm[np.asarray(arrs["edge_rev"])]
    pre = np.where(smask[src], pre, NEG_INF)
    scores = np.asarray(arrs["log_psi_e"]) + pre[:, :, None]   # (E, S, S)
    m = np.maximum(scores.max(axis=1, keepdims=True), NEG_INF)
    cand = np.squeeze(m, 1) + np.log(
        np.maximum(np.exp(scores - m).sum(axis=1), 1e-38))
    x = np.where(dst_mask, cand, NEG_INF)
    mz = np.maximum(x.max(axis=1, keepdims=True), NEG_INF)
    z = np.squeeze(mz, 1) + np.log(np.maximum(
        np.where(dst_mask, np.exp(x - mz), 0.0).sum(axis=1), 1e-38))
    cand = np.where(dst_mask, cand - z[:, None], NEG_INF)
    d = np.where(dst_mask, np.abs(cand - logm), 0.0)
    resid = np.where(emask, d.max(axis=1), 0.0)
    return float(resid.max())


class AdmissionPolicy:
    """Base admission policy: *which staged request enters a bucket when*.

    The pipeline calls the hooks below at fixed points; the base
    implementations are exactly the pre-policy FIFO behavior, so a subclass
    overrides only the decisions it changes. Policies are addressable by
    string through ``ADMISSION_POLICIES`` (``get_admission_policy``), the
    same registry pattern as schedulers and update backends, so
    ``BPConfig(admission="residual")`` stays serializable end-to-end.

    Hooks (called on the serving thread):

    - ``score(pgm, arrs, group)`` -- per-request effort estimate computed at
      staging time (``arrs`` are the padded numpy arrays, pre-``device_put``).
    - ``features(pgm, arrs, group)`` -- extra per-request feature values
      (coupling stats) for the learned effort model; default none.
    - ``ready(group, now)`` -- may a new bucket open from this group now?
      (``windowed`` answers no while it gathers a fuller bucket.)
    - ``pick_group(groups, now)`` -- which ready group admits when a slot
      frees; default is cross-group FIFO by oldest staged head, the
      no-starvation choice.
    - ``pick_many(groups, now, free)`` -- slot packing: the groups to open
      buckets from *this admission cycle*, up to ``free`` slots. The
      default delegates to a single ``pick_group`` call (one group per
      cycle iteration -- bitwise the legacy path); a packing policy returns
      several at once so narrow co-arriving groups dispatch in the same
      device cycle instead of across cycles.
    - ``take(group, width, slot=None)`` -- remove and return up to ``width``
      staged requests; ``slot`` is the resident bucket being backfilled
      (``None`` when opening a fresh bucket).
    - ``cull(group, now)`` -- staged requests to give up on *before*
      admission (released as ``status="evicted"`` with prior beliefs);
      default none. The deadline policy culls expired requests.
    - ``should_evict(slot, rid, rounds, residual, now)`` -- mid-flight
      eviction: called per live unfinished request after each chunk sync
      (only when ``evicts`` is True) with its cumulative rounds and current
      max residual; True releases it as ``status="evicted"`` with its
      partial beliefs. Default never.
    - ``observe(group, score, rounds, service_s=...)`` -- completion
      feedback: the rounds a released request actually ran and its wall
      service time (feeds effort + pace calibration). Not called for
      evicted requests (their rounds are not a convergence count).
    - ``forget(rid)`` -- the request left its slot (released or evicted);
      drop any per-rid tracking state.
    - ``pull_bonus()`` -- extra requests the host should pull beyond
      ``prefetch`` (``windowed`` raises it to fill a held bucket).
    - ``wait_hint(groups, now)`` -- seconds the drive loop may sleep when
      nothing is admissible but work is staged (0 = no wait needed).
    """

    name = "base"
    #: policies that may evict (mid-flight or staged) set this True; the
    #: pipeline then fetches per-graph residuals at each sync and runs the
    #: cull/should_evict hooks (False skips that work entirely).
    evicts = False

    def __init__(self):
        self.pipeline: "ServingPipeline | None" = None

    def bind(self, pipeline: "ServingPipeline") -> "AdmissionPolicy":
        """Attach to the driving pipeline (called once from its
        constructor); returns self so construction chains. A policy
        instance holds pipeline-coupled state (the bound pipeline, any
        history), so sharing one across pipelines would silently read the
        wrong pipeline's groups/exhaustion -- rebinding refuses instead:
        pass a registry spec string (always constructed fresh) or a new
        instance per pipeline."""
        if self.pipeline is not None and self.pipeline is not pipeline:
            raise ValueError(
                f"{type(self).__name__} instance is already bound to a "
                "pipeline; admission policies are per-pipeline -- use a "
                "registry spec string or a fresh instance")
        self.pipeline = pipeline
        return self

    def score(self, pgm: PGM, arrs: Mapping[str, np.ndarray],
              group: _Group) -> float:
        """Effort estimate for one staged request; FIFO scores nothing."""
        return 0.0

    def features(self, pgm: PGM, arrs: Mapping[str, np.ndarray],
                 group: _Group) -> Tuple[float, ...]:
        """Extra per-request feature values for the learned effort model
        (coupling stats); the base policy computes none."""
        return ()

    def ready(self, group: _Group, now: float) -> bool:
        """May a fresh bucket open from ``group`` now? FIFO: always."""
        return True

    def pick_group(self, groups: Iterable[_Group], now: float):
        """The group to admit from: cross-group FIFO over ready groups
        (oldest staged head first), or ``None`` when nothing is
        admissible."""
        ready = [g for g in groups if g.queue and self.ready(g, now)]
        return min(ready, key=lambda g: g.queue[0].t_enqueue, default=None)

    def pick_many(self, groups: Iterable[_Group], now: float,
                  free: int) -> "List[_Group]":
        """The groups to open buckets from this admission cycle (at most
        ``free``, one bucket each). The default delegates to a single
        :meth:`pick_group` call -- exactly the legacy one-group-per-cycle
        admission, so every non-packing policy keeps its bitwise behavior;
        packing policies override to fill several free slots at once."""
        g = self.pick_group(groups, now)
        return [] if g is None else [g]

    def take(self, group: _Group, width: int,
             slot: "_Slot | None" = None) -> List[_Staged]:
        """Remove and return up to ``width`` staged requests from
        ``group``'s queue. FIFO pops the oldest."""
        return [group.queue.popleft()
                for _ in range(min(width, len(group.queue)))]

    def cull(self, group: _Group, now: float) -> List[_Staged]:
        """Remove and return staged requests to give up on before they are
        ever admitted (the deadline policy's expired-in-queue path); the
        base policy never culls."""
        return []

    def should_evict(self, slot: _Slot, rid: int, rounds: int,
                     residual: float, now: float) -> bool:
        """Mid-flight eviction decision for one live unfinished request
        (called per chunk sync, only when ``evicts``); the base policy
        never evicts."""
        return False

    def observe(self, group: _Group, score: float, rounds: int,
                service_s: float = 0.0,
                extra: Tuple[float, ...] = ()) -> None:
        """Completion feedback for one released request; FIFO ignores it."""

    def forget(self, rid: int) -> None:
        """Request ``rid`` left its slot; drop any per-rid tracking."""

    def pull_bonus(self) -> int:
        """Extra pull target beyond ``prefetch`` (0 for FIFO)."""
        return 0

    def wait_hint(self, groups: Iterable[_Group], now: float) -> float:
        """Seconds the drive loop may sleep when work is staged but nothing
        is admissible (only a holding policy returns > 0)."""
        return 0.0


class FIFOAdmission(AdmissionPolicy):
    """Arrival-order admission -- the default, and bitwise the pre-policy
    pipeline: buckets open from the group whose staged head has waited
    longest, requests enter in enqueue order, backfill pops the oldest.
    Zero scoring cost; the right choice when requests are effort-homogeneous
    or latency fairness dominates."""

    name = "fifo"


class ResidualAdmission(AdmissionPolicy):
    """Expected-effort admission: co-batch requests that will run similarly
    long, so stragglers stop pinning buckets of already-finished peers.

    Residual BP (Elidan et al.) orders *message* updates by residual --
    spend work where convergence is farthest. This policy lifts that idea
    one level up, to request admission: every staged request is scored by
    its **residual at admit** (one numpy BP step from uniform messages, the
    paper's r(m) evaluated at the initial state), and a per-kind
    :class:`~repro.core.batch.RoundsHistory` calibrates that proxy into
    expected rounds from what similar requests actually ran. Buckets are
    then composed by similarity: a fresh bucket seeds with the *oldest*
    staged request and fills with the nearest expected-effort neighbors;
    backfill picks the staged request closest to the mean expected effort
    of the slot's live occupants. Fast-converging requests ride
    fast buckets that release early; long-running ones co-batch and do
    useful work together -- the gated chunk body then wastes no sweeps on
    mixed-effort buckets (see ``BENCH_batch.json`` ``admission_policies``).

    No-starvation: a fresh bucket always seeds with the oldest head, and a
    head skipped by ``aging`` consecutive takes is force-admitted next, so
    on any stream in which takes keep happening every staged request is
    admitted after at most ``aging`` further takes once it reaches the
    head. ``history_capacity`` bounds per-kind feedback kept
    (:class:`~repro.core.batch.RoundsHistory`)."""

    name = "residual"

    def __init__(self, aging: int = 16, history_capacity: int = 64,
                 history: RoundsHistory | None = None):
        super().__init__()
        if aging < 1:
            raise ValueError(f"aging must be >= 1, got {aging}")
        self.aging = aging
        # An explicit ``history`` may be shared across pipelines (it locks
        # internally): the router tier passes every replica one instance so
        # effort calibration pools instead of cold-starting per replica.
        # The *policy* stays per-pipeline (bind() enforces that); only the
        # observation store is shared.
        self.history = history if history is not None \
            else RoundsHistory(capacity=history_capacity)

    def score(self, pgm: PGM, arrs: Mapping[str, np.ndarray],
              group: _Group) -> float:
        return _residual_at_admit(arrs)

    def expected(self, group: _Group, score: float) -> float:
        """Expected rounds for an admission score: the history's prediction
        (learned ridge model by default, see
        :class:`~repro.core.batch.RoundsHistory`), falling back to the raw
        score before any feedback exists."""
        return self.history.expect(group.ceilings, score,
                                   default=float(score))

    def take(self, group: _Group, width: int,
             slot: "_Slot | None" = None) -> List[_Staged]:
        # Selection cost is O(queue * history_capacity) per take (one
        # expected() per staged element, each a bounded history scan). The
        # online path bounds the queue by ``prefetch``, so this is small
        # per cycle; for huge *materialized* streams (prefetch=None)
        # prefer a finite prefetch to keep admission work linear.
        q = group.queue
        width = min(width, len(q))
        if width == 0:
            return []
        head = q[0]
        anchor = None
        forced = head.passed_over >= self.aging
        if slot is not None and not forced:
            live = [self.expected(group, slot.meta[r].score)
                    for r in slot.live if r is not None]
            if live:
                anchor = sum(live) / len(live)
        if anchor is None:
            anchor = self.expected(group, head.score)
            forced = True       # fresh bucket (or aged head): seed = oldest
        exp = [self.expected(group, s.score) for s in q]
        pick = set(heapq.nsmallest(width, range(len(q)),
                                   key=lambda i: (abs(exp[i] - anchor), i)))
        if forced and 0 not in pick:
            pick.remove(max(pick, key=lambda i: (abs(exp[i] - anchor), i)))
            pick.add(0)
        if 0 not in pick:
            head.passed_over += 1
        chosen = [q[i] for i in sorted(pick)]
        kept = [s for i, s in enumerate(q) if i not in pick]
        q.clear()
        q.extend(kept)
        return chosen

    def observe(self, group: _Group, score: float, rounds: int,
                service_s: float = 0.0,
                extra: Tuple[float, ...] = ()) -> None:
        self.history.observe(group.ceilings, score, rounds, extra=extra)


class WindowedAdmission(AdmissionPolicy):
    """Delay-for-fullness admission -- the latency-vs-throughput knob.

    FIFO opens a bucket the moment one request is staged, so bursty or slow
    arrival processes produce narrow buckets that under-fill the device.
    This policy *holds* a group's first admission while its staged queue is
    below ``target`` (default: the pipeline's ``max_batch``), for at most
    ``window_s`` seconds of the head request's waiting time -- trading a
    bounded p50 admission wait for fuller buckets (fewer compiles, fewer
    per-bucket fixed costs, better device occupancy). While holding it
    raises the host's pull target (``pull_bonus``) so the window actually
    fills instead of merely waiting. Backfill of already-open buckets is
    never delayed (filling a running bucket is pure win), and exhaustion of
    the stream makes every group immediately ready, so a final partial
    bucket never waits out its window.

    The ``window_s`` bound is guaranteed for feeder-backed
    (``ingest_threads``) and non-blocking sources. A plain iterator that
    *blocks* in ``__next__`` can overshoot it: the fill pull runs on the
    serving thread, and a blocked ``next`` cannot be interrupted mid-call
    -- the general blocking-source caveat, so pair ``windowed`` with
    ``ingest_threads`` when the source can stall."""

    name = "windowed"

    def __init__(self, window_s: float = 0.01, target: int | None = None):
        super().__init__()
        if window_s < 0:
            raise ValueError(f"window_s must be >= 0, got {window_s}")
        if target is not None and target < 1:
            raise ValueError(f"target must be >= 1, got {target}")
        self.window_s = window_s
        self.target = target

    def _target(self) -> int:
        assert self.pipeline is not None
        return self.target or self.pipeline.max_batch or 0

    def ready(self, group: _Group, now: float) -> bool:
        assert self.pipeline is not None
        if self.pipeline._exhausted:
            return True
        t = self._target()
        if t and len(group.queue) >= t:
            return True
        return now - group.queue[0].t_enqueue >= self.window_s

    def pull_bonus(self) -> int:
        assert self.pipeline is not None
        t = self._target()
        if not t:
            return 0
        return sum(max(0, t - len(g.queue))
                   for g in self.pipeline._groups.values() if g.queue)

    def wait_hint(self, groups: Iterable[_Group], now: float) -> float:
        rem = [self.window_s - (now - g.queue[0].t_enqueue)
               for g in groups if g.queue]
        rem = [r for r in rem if r > 0]
        return min(rem) if rem else 0.0


def _coupling_stats(arrs: Mapping[str, np.ndarray]) -> Tuple[float, float]:
    """(mean, std) of |log pairwise potential| over real edge entries --
    the coupling-strength features the learned effort model regresses on
    (strong coupling correlates with slow convergence). Numpy on the
    staging path, same rationale as ``_residual_at_admit``."""
    lpe = np.asarray(arrs["log_psi_e"])                 # (E, S, S)
    emask = np.asarray(arrs["edge_mask"]).astype(bool)
    if not emask.any():
        return (0.0, 0.0)
    mag = np.abs(np.where(np.isfinite(lpe), lpe, 0.0))[emask]
    return (float(mag.mean()), float(mag.std()))


class SweepClock:
    """Deterministic virtual clock for SLA tests and benches: time is
    *device sweeps*, not wall seconds.

    Inject as ``ServingPipeline(clock=...)``: the pipeline reads ``now``
    via ``clock()`` and, because this class defines ``on_chunk``, advances
    it by ``tau`` virtual seconds per device sweep at every chunk sync.
    Requests staged up front at t=0 with SLOs in sweep units then make the
    whole deadline/eviction/attainment story a pure function of scheduling
    decisions -- identical on any machine, never sleeping on wall time.
    ``advance`` lets a test move time by hand (arrival processes)."""

    def __init__(self, tau: float = 1.0):
        if tau <= 0:
            raise ValueError(f"tau must be > 0, got {tau}")
        self.t = 0.0
        self.tau = float(tau)

    def __call__(self) -> float:
        return self.t

    def on_chunk(self, sweeps: int) -> None:
        """Pipeline hook: one chunk of ``sweeps`` device sweeps completed."""
        self.t += float(sweeps) * self.tau

    def advance(self, dt: float) -> None:
        """Move virtual time forward by ``dt`` seconds (manual control)."""
        self.t += float(dt)


class DeadlineAdmission(AdmissionPolicy):
    """SLA-aware admission: earliest-predicted-slack ordering, slot
    packing, and eviction of work that will not make its deadline.

    Requests carry a latency budget from the stream (``(rid, pgm, slo_s)``
    items, or ``default_slo``); *slack* is ``deadline - now - predicted
    service time``, with service predicted as the learned
    :class:`~repro.core.batch.RoundsHistory` rounds estimate times an
    EWMA-calibrated seconds-per-round pace per shape family. Three
    decisions follow:

    - **Admission order** (``take`` / ``pick_group``): least slack first --
      EDF generalized by predicted effort, so a lax request yields to an
      urgent one even when it arrived earlier. Requests without a deadline
      have infinite slack and order last; the same aging counter as
      ``residual`` force-admits a head skipped ``aging`` times, so they
      cannot starve under a sustained deadlined stream.
    - **Slot packing** (``pick_many``): fill *all* free slots in one
      admission cycle with the most-urgent distinct groups, so co-arriving
      narrow shape families dispatch in the same device cycle instead of
      serializing one per cycle.
    - **Eviction** (``should_evict`` / ``cull``): after each chunk sync the
      per-graph ``BPState`` residual gives the converging-too-slowly
      signal. A live request is hopeless when its deadline already passed,
      or when the observed residual decay rate (log-residual per round,
      the faster of last-interval and whole-trajectory slope, judged
      after ``grace`` syncs) projects convergence to
      ``eps`` past its deadline -- it is then released immediately as
      ``status="evicted"`` with its partial beliefs, freeing the slot for
      work that can still make its SLO. ``cull`` likewise gives up on
      staged requests whose deadline expired while queued (prior beliefs,
      zero service). ``evict=False`` keeps slack ordering but never gives
      up on work.

    ``safety`` scales the projected remaining time before comparing
    against the deadline (>1 = evict earlier); ``min_rate`` is the decay
    rate below which a request counts as stalled (projected never).
    ``history`` may be shared across pipelines (the router tier pools it),
    exactly as with ``residual``."""

    name = "deadline"

    def __init__(self, default_slo: float | None = None,
                 safety: float = 1.0, grace: int = 2,
                 min_rate: float = 1e-4, evict: bool = True,
                 pack: bool = True, aging: int = 16,
                 history_capacity: int = 64,
                 history: RoundsHistory | None = None):
        super().__init__()
        if default_slo is not None and default_slo < 0:
            raise ValueError(f"default_slo must be >= 0, got {default_slo}")
        if grace < 1:
            raise ValueError(f"grace must be >= 1, got {grace}")
        if aging < 1:
            raise ValueError(f"aging must be >= 1, got {aging}")
        self.default_slo = default_slo
        self.safety = float(safety)
        self.grace = grace
        self.min_rate = float(min_rate)
        self.evicts = bool(evict)
        self.pack = bool(pack)
        self.aging = aging
        self.history = history if history is not None \
            else RoundsHistory(capacity=history_capacity)
        self._pace: Dict[tuple, float] = {}     # kind -> EWMA sec/round
        self._pace_all: float | None = None
        #: rid -> (rounds, log residual, syncs seen, first-sync rounds,
        #: first-sync log residual) as of the last chunk sync
        self._track: Dict[int, Tuple[int, float, int, int, float]] = {}

    # -- scoring / features ------------------------------------------------

    def score(self, pgm: PGM, arrs: Mapping[str, np.ndarray],
              group: _Group) -> float:
        return _residual_at_admit(arrs)

    def features(self, pgm: PGM, arrs: Mapping[str, np.ndarray],
                 group: _Group) -> Tuple[float, ...]:
        return _coupling_stats(arrs)

    # -- slack -------------------------------------------------------------

    def _slo_of(self, staged: _Staged) -> float | None:
        return staged.slo if staged.slo is not None else self.default_slo

    def _deadline(self, staged: _Staged) -> float | None:
        slo = self._slo_of(staged)
        return None if slo is None else staged.t_enqueue + slo

    def _pace_of(self, ceilings: tuple) -> float:
        pace = self._pace.get(ceilings, self._pace_all)
        return 0.0 if pace is None else pace

    def slack(self, group: _Group, staged: _Staged, now: float) -> float:
        """Predicted slack seconds: time to deadline minus predicted
        service (expected rounds x calibrated pace). Infinite without a
        deadline; cold pace predicts zero service (pure EDF)."""
        deadline = self._deadline(staged)
        if deadline is None:
            return float("inf")
        est = self.history.expect(group.ceilings, staged.score,
                                  default=0.0, extra=staged.extra)
        return deadline - now - est * self._pace_of(group.ceilings)

    def _urgency(self, group: _Group, now: float) -> float:
        return min(self.slack(group, s, now) for s in group.queue)

    # -- admission ---------------------------------------------------------

    def pick_group(self, groups: Iterable[_Group], now: float):
        ready = [g for g in groups if g.queue and self.ready(g, now)]
        return min(ready, key=lambda g: (self._urgency(g, now),
                                         g.queue[0].t_enqueue, g.ceilings),
                   default=None)

    def pick_many(self, groups: Iterable[_Group], now: float,
                  free: int) -> List[_Group]:
        if not self.pack:
            return super().pick_many(groups, now, free)
        ready = [g for g in groups if g.queue and self.ready(g, now)]
        ready.sort(key=lambda g: (self._urgency(g, now),
                                  g.queue[0].t_enqueue, g.ceilings))
        return ready[:free]

    def take(self, group: _Group, width: int,
             slot: "_Slot | None" = None) -> List[_Staged]:
        q = group.queue
        width = min(width, len(q))
        if width == 0:
            return []
        now = self.pipeline.clock() if self.pipeline is not None else 0.0
        order = sorted(range(len(q)),
                       key=lambda i: (self.slack(group, q[i], now),
                                      q[i].t_enqueue, q[i].rid))
        pick = set(order[:width])
        head = q[0]
        if 0 not in pick:
            if head.passed_over >= self.aging:      # aged: force-admit
                pick.remove(order[width - 1])
                pick.add(0)
            else:
                head.passed_over += 1
        chosen = [q[i] for i in sorted(pick)]
        kept = [s for i, s in enumerate(q) if i not in pick]
        q.clear()
        q.extend(kept)
        return chosen

    def cull(self, group: _Group, now: float) -> List[_Staged]:
        if not self.evicts:
            return []
        expired = [s for s in group.queue
                   if (d := self._deadline(s)) is not None and now >= d]
        if expired:
            gone = set(id(s) for s in expired)
            kept = [s for s in group.queue if id(s) not in gone]
            group.queue.clear()
            group.queue.extend(kept)
        return expired

    # -- eviction ----------------------------------------------------------

    def should_evict(self, slot: _Slot, rid: int, rounds: int,
                     residual: float, now: float) -> bool:
        meta = slot.meta[rid]
        slo = meta.slo if meta.slo is not None else self.default_slo
        if slo is None:
            return False
        eps = self.pipeline.engine.config.eps \
            if self.pipeline is not None else 1e-3
        if residual <= eps:
            return False                # converged: releases on this sync
        deadline = meta.t_enqueue + slo
        if now >= deadline:
            return True                 # already missed: stop burning sweeps
        logr = float(np.log(max(residual, 1e-300)))
        prev = self._track.get(rid)
        if prev is None:
            self._track[rid] = (rounds, logr, 1, rounds, logr)
            return False                # need a trajectory before judging
        rounds_prev, logr_prev, syncs, r0, logr0 = prev
        self._track[rid] = (rounds, logr, syncs + 1, r0, logr0)
        if syncs + 1 < self.grace:
            return False
        dr = rounds - rounds_prev
        if dr <= 0:
            return False
        # Residual decay is non-monotone: a transient plateau in the last
        # interval must not doom a request whose whole-trajectory slope is
        # healthy, so project with the more optimistic of the two rates.
        rate = (logr_prev - logr) / dr  # log-residual decay per round
        if rounds > r0:
            rate = max(rate, (logr0 - logr) / (rounds - r0))
        if rate <= self.min_rate:       # stalled / diverging: never makes it
            return True
        est_rounds = (logr - float(np.log(eps))) / rate
        eta = now + self.safety * est_rounds * self._pace_of(
            slot.group.ceilings)
        return eta > deadline

    # -- feedback ----------------------------------------------------------

    def observe(self, group: _Group, score: float, rounds: int,
                service_s: float = 0.0,
                extra: Tuple[float, ...] = ()) -> None:
        self.history.observe(group.ceilings, score, rounds, extra=extra)
        if rounds > 0 and service_s > 0:
            pace = service_s / rounds
            old = self._pace.get(group.ceilings)
            self._pace[group.ceilings] = pace if old is None \
                else 0.5 * old + 0.5 * pace
            self._pace_all = pace if self._pace_all is None \
                else 0.5 * self._pace_all + 0.5 * pace

    def forget(self, rid: int) -> None:
        self._track.pop(rid, None)


#: name -> AdmissionPolicy class; names are the canonical serialized form
#: (``BPConfig(admission=...)`` / ``serve_async(admission=...)``). A
#: ``Registry`` (dict subclass): plain-dict reads keep working.
ADMISSION_POLICIES: Registry[type] = Registry("admission policy", {
    "fifo": FIFOAdmission,
    "residual": ResidualAdmission,
    "windowed": WindowedAdmission,
    "deadline": DeadlineAdmission,
})


def register_admission_policy(name: str, *, overwrite: bool = False):
    """Class decorator registering an :class:`AdmissionPolicy` subclass
    under ``name`` (lowercased), making it addressable by string spec --
    ``serve_async(..., admission="mine")`` -- exactly like
    ``register_scheduler`` does for schedulers. The class must be
    constructible from keyword arguments so specs stay serializable.
    Duplicate names raise ``ValueError`` unless ``overwrite=True``."""
    return ADMISSION_POLICIES.register(name, overwrite=overwrite)


def list_admission_policies() -> List[str]:
    """Sorted registered admission-policy names (valid
    ``BPConfig.admission`` / ``serve_async(admission=...)`` specs)."""
    return ADMISSION_POLICIES.names()


def get_admission_policy(spec, **kwargs) -> AdmissionPolicy:
    """Resolve an admission-policy spec: a registry name (+ constructor
    kwargs) or an already-built :class:`AdmissionPolicy` instance (kwargs
    must then be empty). The string form is what ``BPConfig.admission``
    serializes."""
    if isinstance(spec, str):
        return ADMISSION_POLICIES.lookup(spec)(**kwargs)
    if kwargs:
        raise ValueError("admission kwargs only apply to string specs, got "
                         f"instance {type(spec).__name__} plus {kwargs}")
    return spec


# ----------------------------------------------------- threaded ingestion --

_FEEDER_DONE = object()
_FEEDER_EXHAUSTED = object()


class _IngestFeeder:
    """Feeder threads pulling the request iterator into a bounded queue.

    The stream's ``__next__`` runs on daemon feeder threads (serialized by
    a lock, so any plain iterator is safe); pulled items enter a
    ``queue.Queue(maxsize)`` whose bound is the host-memory guard -- a full
    queue blocks the *feeder*, never the serving loop. Each item is stamped
    under the lock with its arrival index (the auto-rid, so rid assignment
    matches the unthreaded path item for item) and its pull time (the
    request's ``t_enqueue``). Iterator exceptions are captured and re-raised
    on the serving thread once the queue drains. ``close()`` (called from
    ``serve``'s finally, so an abandoned generator or a staging-time error
    cannot leak threads) stops the workers: puts are bounded waits
    re-checking the stop flag, so a worker blocked on a full queue exits
    promptly instead of pinning the source forever."""

    def __init__(self, it: Iterator, threads: int, maxsize: int,
                 clock=time.perf_counter):
        self._it = it
        self._clock = clock
        self._lock = threading.Lock()
        self._q: _queue.Queue = _queue.Queue(maxsize=max(1, maxsize))
        self._n = 0
        self._live = threads
        self._error: BaseException | None = None
        self._stop = False
        self._threads = [threading.Thread(target=self._worker, daemon=True)
                         for _ in range(threads)]
        for t in self._threads:
            t.start()

    def _put(self, x) -> bool:
        """Bounded-wait put that aborts once ``close()`` ran (a plain
        blocking put could pin a worker on a full queue forever)."""
        while not self._stop:
            try:
                self._q.put(x, timeout=0.05)
                return True
            except _queue.Full:
                continue
        return False

    def _worker(self) -> None:
        while True:
            with self._lock:
                if self._error is not None or self._stop:
                    break
                try:
                    item = next(self._it)
                except StopIteration:
                    break
                except BaseException as e:     # surface on serving thread
                    self._error = e
                    break
                rid, self._n = self._n, self._n + 1
                t = self._clock()
            if not self._put((rid, item, t)):  # blocks when full: the bound
                return
        self._put(_FEEDER_DONE)

    def close(self, *, join_timeout: float = 2.0) -> None:
        """Stop the feeder: workers quit pulling at their next check, the
        queue is drained so any worker blocked in ``put`` unblocks (dropping
        staged-but-unserved items -- the caller abandoned them), and worker
        threads are joined. A worker blocked inside the *source's*
        ``__next__`` cannot be interrupted mid-call; the bounded join leaves
        such a (daemon) thread behind rather than hanging shutdown -- the
        general blocking-source caveat."""
        self._stop = True
        while True:
            try:
                self._q.get_nowait()
            except _queue.Empty:
                break
        deadline = time.perf_counter() + max(0.0, join_timeout)
        for t in self._threads:
            t.join(timeout=max(0.0, deadline - time.perf_counter()))

    def get(self, block: bool):
        """Next ``(auto_rid, item, t_pull)``; ``None`` when nothing is
        available right now (non-blocking miss), or the exhausted sentinel
        once every feeder thread has finished."""
        while True:
            try:
                got = self._q.get(block=block)
            except _queue.Empty:
                return None
            if got is _FEEDER_DONE:
                self._live -= 1
                if self._live == 0:
                    if self._error is not None:
                        raise self._error
                    return _FEEDER_EXHAUSTED
                continue
            return got


# --------------------------------------------------------------- pipeline --

class ServingPipeline:
    """The asynchronous serving driver (see module docstring).

    One pipeline instance serves one stream through one ``BPEngine``.
    ``serve(stream)`` is a generator yielding a ``RequestRecord`` per
    request *in completion order* -- consume it incrementally for online
    workloads, or use :func:`serve_async` to collect everything.

    Knobs: ``slots`` bounds resident buckets stepped per cycle (2 =
    double-buffering; 1 reproduces the legacy serve cadence exactly);
    ``prefetch`` is the staged-request low-water mark the host keeps pulled
    ahead of admission (``None`` = drain the stream eagerly up front);
    ``evacuate``/``compact`` toggle the straggler policies; ``admission``
    picks the admission policy -- a registry spec string (``"fifo"`` |
    ``"residual"`` | ``"windowed"``, constructed with ``admission_kwargs``)
    or a prebuilt :class:`AdmissionPolicy`; ``None`` defers to the engine's
    ``BPConfig.admission``. ``ingest_threads=N`` moves the stream pull onto
    ``N`` feeder threads behind a bounded queue (``ingest_queue`` items,
    default max(prefetch, 2N)) so a source that blocks in ``__next__`` no
    longer stalls device dispatch. ``record_events=False`` drops the
    per-request evacuation/compaction/width logs (counters stay), bounding
    host memory on indefinitely long streams; ``plan`` maps a
    ``bucket_key`` to explicit group ceilings (the materialized-stream
    compat path) -- without it each request pads to its own deterministic
    ``bucket_shape`` ceilings, the online policy.

    The stream may yield ``PGM``s (rid = arrival order), explicit
    ``(rid, PGM)`` pairs, or ``(rid, PGM, slo_s)`` triples attaching a
    latency budget (seconds from enqueue; ``rid=None`` keeps arrival-order
    rids) that deadline-aware policies read and every ``RequestRecord``
    reports via ``within_slo``. ``clock`` replaces the pipeline's time
    source (default ``time.perf_counter``) -- inject a
    :class:`SweepClock` for deterministic virtual-time tests/benches; a
    clock exposing ``on_chunk(sweeps)`` is advanced by the pipeline at
    every chunk sync. Per-request RNG keys are ``fold_in(rng, rid)``,
    so results are independent of every pipeline knob -- admission policy
    included; only the *padded shape* policy (plan vs online) can alter
    stochastic-scheduler trajectories, the caveat shared with ``run_many``.
    Without ``ingest_threads`` the stream is pulled on the serving thread:
    a source that blocks in ``__next__`` delays servicing.

    Lifecycle: a pipeline is also a context manager -- ``with
    ServingPipeline(...) as pipe`` guarantees ``close()`` on exit, which
    stops and joins any live ingest feeder threads (an abandoned ``serve``
    generator already closes its own feeder, but only once its ``finally``
    runs; owners that must not leak threads call ``close()`` explicitly --
    the router tier's replica teardown does).
    """

    def __init__(self, engine: BPEngine, rng: jax.Array, *,
                 growth: float = 2.0, max_batch: int | None = None,
                 chunk_rounds: int | None = None, evacuate: bool = True,
                 compact: bool = True, slots: int = 2,
                 prefetch: int | None = 8,
                 record_events: bool = True,
                 plan: Dict[tuple, tuple] | None = None,
                 admission: "str | AdmissionPolicy | None" = None,
                 admission_kwargs: Mapping | None = None,
                 ingest_threads: int = 0,
                 ingest_queue: int | None = None,
                 clock=None):
        if engine.is_serial:
            raise NotImplementedError(
                "serving needs a frontier scheduler (srbp is host-serial)")
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if max_batch is not None and max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if ingest_threads < 0:
            raise ValueError(
                f"ingest_threads must be >= 0, got {ingest_threads}")
        cfg = engine.config
        self.engine = engine
        self.rng = rng
        self.growth = growth
        self.max_batch = max_batch
        self.chunk = (chunk_rounds or cfg.chunk_rounds
                      or max(1, cfg.max_rounds // 16))
        self.evacuate = evacuate
        self.compact = compact
        self.slots = slots
        self.prefetch = prefetch
        self.record_events = record_events
        self.plan = plan
        self.ingest_threads = ingest_threads
        self.ingest_queue = ingest_queue
        self.clock = clock if clock is not None else time.perf_counter
        self._clock_on_chunk = getattr(self.clock, "on_chunk", None)
        if admission is None:
            admission = getattr(cfg, "admission", "fifo")
            if admission_kwargs is None:
                admission_kwargs = dict(getattr(cfg, "admission_kwargs", ()))
        self.policy = get_admission_policy(
            admission, **dict(admission_kwargs or {})).bind(self)
        self.stats = AsyncServeStats(policy=self.policy.name)
        self._groups: Dict[tuple, _Group] = {}
        self._exhausted = False
        self._arrival = 0
        # Duplicate-rid detection only applies once the stream supplies
        # explicit (rid, PGM) pairs; auto-assigned rids are unique by
        # construction, so the common online path stores nothing per
        # request (long-lived streams must not grow host memory).
        self._explicit_rids = False
        self._seen_rids: set[int] = set()
        self._feeder: _IngestFeeder | None = None
        self._closed = False

    # -- staging (host padding + device_put prefetch) ----------------------

    def _group_for(self, pgm: PGM) -> _Group:
        if self.plan is not None:
            key = bucket_key(pgm, self.growth)
            ceilings = self.plan[key]
        else:
            key = ceilings = bucket_shape(pgm, self.growth)
        group = self._groups.get(key)
        if group is None:
            group = self._groups[key] = _Group(ceilings)
        return group

    def _stage(self, rid: int, pgm: PGM, t_enqueue: float,
               slo: float | None = None) -> None:
        if self._explicit_rids:         # rid = RNG fold_in index: must be 1:1
            if rid in self._seen_rids:
                raise ValueError(f"duplicate request id {rid} in stream")
            self._seen_rids.add(rid)
        group = self._group_for(pgm)
        e, v, s, re_, rv = group.ceilings
        arrs = pad_pgm_arrays(pgm, n_edges=e, n_vertices=v, n_states=s)
        score = self.policy.score(pgm, arrs, group)
        extra = tuple(self.policy.features(pgm, arrs, group))
        # The prefetch: H2D starts now, overlapped with device compute.
        elem = PGM(n_real_vertices=rv, n_real_edges=re_,
                   **jax.device_put(arrs))
        group.queue.append(_Staged(
            rid, elem, jax.random.fold_in(self.rng, rid), t_enqueue,
            score=score, slo=slo, extra=extra))
        self.stats.staged += 1

    def _staged_count(self) -> int:
        return sum(len(g.queue) for g in self._groups.values())

    def _pump(self, it, target: float, block: bool = False) -> None:
        """Pull requests until ``target`` are staged (or the stream ends).
        With a feeder source, ``block=False`` only drains what the feeder
        already pulled (an empty feeder queue returns immediately -- the
        non-stalling property); a plain iterator blocks in ``next`` either
        way."""
        while not self._exhausted and self._staged_count() < target:
            if isinstance(it, _IngestFeeder):
                got = it.get(block)
                if got is None:
                    return
                if got is _FEEDER_EXHAUSTED:
                    self._exhausted = True
                    return
                rid_auto, item, t = got
            else:
                try:
                    item = next(it)
                except StopIteration:
                    self._exhausted = True
                    return
                t = self.clock()
                rid_auto = self._arrival
            slo = None
            if isinstance(item, tuple):
                if len(item) == 3:
                    rid, pgm, slo = item
                    slo = None if slo is None else float(slo)
                else:
                    rid, pgm = item
                if rid is None:         # keep arrival-order rid assignment
                    rid = rid_auto
                else:
                    self._explicit_rids = True
            else:
                rid, pgm = rid_auto, item
            self._arrival += 1
            self._stage(int(rid), pgm, t, slo=slo)

    # -- slot lifecycle ----------------------------------------------------

    def _admit(self, group: _Group) -> _Slot:
        """Open a resident bucket from the group's queue: width =
        min(max_batch, pending), composition chosen by the admission
        policy, stacked from prefetched elements."""
        width = min(self.max_batch or len(group.queue), len(group.queue))
        take = self.policy.take(group, width)
        batch = BatchedPGM(pgm=jax.tree.map(
            lambda *xs: jnp.stack(xs), *[s.elem for s in take]))
        keys = jnp.stack([s.key for s in take])
        state = self.engine.init(batch, keys)
        t = self.clock()
        self.stats.buckets_opened += 1
        if self.record_events:
            self.stats.admission_widths.append(len(take))
        return _Slot(group=group, state=state,
                     live=[s.rid for s in take],
                     rounds_host=np.zeros(len(take), np.int64),
                     r_before=np.zeros(len(take), np.int64),
                     meta={s.rid: _AdmitMeta(s.t_enqueue, t, s.score,
                                             slo=s.slo, extra=s.extra)
                           for s in take})

    def _release(self, slot: _Slot, j: int, rounds: int) -> RequestRecord:
        rid = slot.live[j]
        assert rid is not None
        result = self.engine._slice_result(slot.state, j)
        slot.live[j] = None
        self.stats.evacuated += 1
        if self.record_events:      # O(requests) log; off for infinite streams
            self.stats.evacuation_log.append((self.stats.chunks, rid))
        meta = slot.meta.pop(rid)
        t_done = self.clock()
        self.policy.observe(slot.group, meta.score, rounds,
                            service_s=max(t_done - meta.t_admit, 0.0),
                            extra=meta.extra)
        self.policy.forget(rid)
        return RequestRecord(rid=rid, result=result,
                             t_enqueue=meta.t_enqueue,
                             t_admit=meta.t_admit, t_done=t_done,
                             slo_s=meta.slo)

    def _evict(self, slot: _Slot, j: int, rounds: int) -> RequestRecord:
        """Release batch slot ``j`` as *evicted*: the partial beliefs at
        the last chunk sync, ``status="evicted"``, sweep accounting under
        ``evicted_sweeps``. The policy is not ``observe``d -- an evicted
        round count is a truncation artifact, not a convergence effort
        sample -- but its per-rid tracking is dropped via ``forget``."""
        rid = slot.live[j]
        assert rid is not None
        result = self.engine._slice_result(slot.state, j)
        slot.live[j] = None
        self.stats.evacuated += 1
        self.stats.evictions += 1
        self.stats.evicted_sweeps += rounds
        if self.record_events:
            self.stats.eviction_log.append((self.stats.chunks, rid))
        meta = slot.meta.pop(rid)
        self.policy.forget(rid)
        return RequestRecord(rid=rid, result=result,
                             t_enqueue=meta.t_enqueue,
                             t_admit=meta.t_admit, t_done=self.clock(),
                             slo_s=meta.slo, status="evicted")

    def _evict_staged(self, group: _Group,
                      staged: _Staged) -> RequestRecord:
        """Give up on a request whose deadline expired while queued: zero
        service, prior beliefs (the BP fixed point of zero rounds --
        normalized unary potentials, since uniform initial messages cancel
        in per-vertex normalization), ``status="evicted"``."""
        lpv = np.asarray(staged.elem.log_psi_v)                # (V, S)
        smask = np.asarray(staged.elem.state_mask).astype(bool)
        x = np.where(smask, lpv, NEG_INF)
        m = np.maximum(x.max(axis=1, keepdims=True), NEG_INF)
        z = m + np.log(np.maximum(
            np.where(smask, np.exp(x - m), 0.0).sum(axis=1, keepdims=True),
            1e-38))
        beliefs = jnp.asarray(np.where(smask, x - z, NEG_INF),
                              dtype=jnp.float32)
        dst = np.asarray(staged.elem.edge_dst)
        n_states = np.asarray(staged.elem.n_states).astype(np.float64)
        logm = jnp.asarray(                 # the round-0 uniform messages
            np.where(smask[dst], -np.log(n_states[dst])[:, None], NEG_INF),
            dtype=jnp.float32)
        cfg = self.engine.config
        hist = jnp.full((cfg.max_rounds if cfg.history else 1,), -1,
                        jnp.int32)
        result = BPResult(
            beliefs=beliefs, logm=logm,
            rounds=jnp.int32(0), updates=jnp.uint32(0),
            converged=jnp.asarray(False),
            max_residual=jnp.float32(staged.score),
            unconverged_history=hist, sched_state=None)
        self.stats.evictions += 1
        if self.record_events:
            self.stats.eviction_log.append((self.stats.chunks, staged.rid))
        t = self.clock()
        self.policy.forget(staged.rid)
        return RequestRecord(rid=staged.rid, result=result,
                             t_enqueue=staged.t_enqueue,
                             t_admit=t, t_done=t,
                             slo_s=staged.slo, status="evicted")

    def _cull(self) -> Iterator[RequestRecord]:
        """Ask the policy for staged requests to give up on (expired
        deadlines) and release them with prior beliefs."""
        now = self.clock()
        for group in self._groups.values():
            for staged in self.policy.cull(group, now):
                yield self._evict_staged(group, staged)

    def _backfill(self, slot: _Slot, j: int) -> None:
        staged = self.policy.take(slot.group, 1, slot=slot)[0]
        slot.state = _load_slot(slot.state, jnp.int32(j), staged.elem,
                                staged.key, scheduler=self.engine.scheduler)
        slot.live[j] = staged.rid
        slot.rounds_host[j] = 0
        slot.meta[staged.rid] = _AdmitMeta(staged.t_enqueue, self.clock(),
                                           staged.score, slo=staged.slo,
                                           extra=staged.extra)
        self.stats.backfilled += 1

    def _maybe_compact(self, slot: _Slot) -> None:
        """Re-bucket survivors into a narrower batch once no backfill can
        ever arrive (queue drained, stream exhausted). Pow2 target widths
        bound recompiles at log2(width) per shape family; surplus slots are
        filled with already-dead entries, which the gated chunk body keeps
        inert."""
        if not (self.compact and self.evacuate and self._exhausted
                and not slot.group.queue):
            return
        keep = [j for j, rid in enumerate(slot.live) if rid is not None]
        if not keep:
            return
        new_w = _pow2_ceil(len(keep))
        if new_w >= slot.width:
            return
        dead = [j for j, rid in enumerate(slot.live) if rid is None]
        chosen = sorted(keep + dead[:new_w - len(keep)])
        self.stats.compactions += 1
        if self.record_events:
            self.stats.compaction_log.append(
                (self.stats.chunks, slot.width, new_w))
        slot.state = _narrow_state(slot.state, chosen)
        slot.live = [slot.live[j] for j in chosen]
        slot.rounds_host = slot.rounds_host[chosen]
        slot.r_before = slot.r_before[chosen]

    def _service(self, slot: _Slot) -> Iterable[RequestRecord]:
        """Sync one stepped slot and apply the straggler policies: account
        sweeps, release finished graphs, backfill freed slots from the
        group queue, then consider compaction."""
        state = slot.state
        r_after = np.asarray(jax.device_get(state.rounds))   # blocks on chunk
        done = np.asarray(jax.device_get(state.done))
        max_rounds = self.engine.config.max_rounds
        inner = self.engine.scheduler.inner_sweeps
        self.stats.chunks += 1
        chunk_sweeps = int(state.chunk_iters) * inner * slot.width
        self.stats.device_sweeps += chunk_sweeps
        self.stats.useful_sweeps += int(sum(
            int(r_after[j] - slot.r_before[j])
            for j in range(slot.width) if slot.live[j] is not None))
        slot.rounds_host = r_after.copy()
        if self._clock_on_chunk is not None:   # virtual clocks tick in sweeps
            self._clock_on_chunk(chunk_sweeps)
        if not self.evacuate:
            # Run-to-completion baseline: release everything only when the
            # whole bucket is finished; never backfill, never compact.
            if all(bool(done[j]) or r_after[j] >= max_rounds
                   for j in range(slot.width)):
                for j in range(slot.width):
                    yield self._release(slot, j, int(r_after[j]))
            return
        for j in range(slot.width):
            if slot.live[j] is None:
                continue
            if bool(done[j]) or r_after[j] >= max_rounds:
                yield self._release(slot, j, int(r_after[j]))
                if slot.group.queue:
                    self._backfill(slot, j)
        if self.policy.evicts:
            # Mid-flight eviction: per-graph residuals at this sync are the
            # converging-too-slowly signal; hopeless requests release now
            # (partial beliefs) instead of burning sweeps to max_rounds.
            resid = np.asarray(jax.device_get(state.max_residual))
            now = self.clock()
            for j in range(slot.width):
                rid = slot.live[j]
                if rid is None:
                    continue
                if self.policy.should_evict(slot, rid, int(r_after[j]),
                                            float(resid[j]), now):
                    yield self._evict(slot, j, int(r_after[j]))
                    if slot.group.queue:
                        self._backfill(slot, j)
        # Slots that went dead while the queue was momentarily empty are
        # revived by later arrivals -- without this, an online straggler
        # bucket would burn dead-slot sweeps while new same-shape requests
        # queue behind it.
        for j in range(slot.width):
            if slot.live[j] is None and slot.group.queue:
                self._backfill(slot, j)
        self._maybe_compact(slot)

    # -- the drive loop ----------------------------------------------------

    def _admissible(self) -> _Group | None:
        """The group the admission policy would open a bucket from now
        (cross-group FIFO under the default policies, so a minority shape
        family cannot starve behind a sustained majority one)."""
        return self.policy.pick_group(self._groups.values(), self.clock())

    def _await_work(self, it) -> bool:
        """Nothing is resident: wait until something becomes admissible.
        Returns False when serving is finished (stream exhausted, nothing
        staged). Blocks on the source only when nothing at all is staged;
        when work is staged but held (an open admission window), pulls
        toward the policy's fill target and sleeps out (a slice of) the
        window instead."""
        if not self._staged_count():
            if self._exhausted:
                return False
            self._pump(it, 1, block=True)
            return bool(self._staged_count()) or not self._exhausted
        before = self._staged_count()
        target = before + self.policy.pull_bonus()
        if target > before:
            self._pump(it, target)
        hint = self.policy.wait_hint(self._groups.values(), self.clock())
        if self._staged_count() == before and hint > 0:
            time.sleep(min(hint, 0.05))
        return True

    def serve(self, stream: Iterable) -> Iterator[RequestRecord]:
        """Drive ``stream`` through the pipeline, yielding one
        ``RequestRecord`` per request in completion order.

        Each cycle: (1) admit staged groups into free slots (which groups,
        which requests, and when are the admission policy's calls), (2)
        dispatch a chunk on every slot (JAX async dispatch -- non-blocking),
        (3) pull and stage new arrivals while the device runs (from the
        feeder queue when ``ingest_threads`` is set, never blocking on the
        source), (4) sync + service each slot, yielding released results.
        Terminates when the stream is exhausted and every admitted graph
        has been released."""
        if self._closed:
            raise ValueError("ServingPipeline is closed")
        it = iter(stream)
        if self.ingest_threads:
            bound = self.ingest_queue or max(self.prefetch or 8,
                                             2 * self.ingest_threads)
            it = self._feeder = _IngestFeeder(it, self.ingest_threads, bound,
                                              clock=self.clock)
        try:
            yield from self._drive(it)
        finally:
            # An abandoned generator or a staging error must not leak
            # feeder threads blocked on a full queue.
            if isinstance(it, _IngestFeeder):
                it.close()
            self._feeder = None

    def close(self) -> None:
        """Shut the pipeline down: stop (and join) any live ingest feeder
        threads and refuse further ``serve`` calls. Idempotent. The
        ``serve`` generator already closes its feeder in a ``finally``;
        ``close`` exists for owners that hold the pipeline itself (the
        router's replica teardown, a ``with`` block) and must guarantee no
        thread survives even if the generator was never started or was
        abandoned mid-``yield``. Staged-but-unserved requests are dropped
        -- the caller abandoned them."""
        self._closed = True
        feeder, self._feeder = self._feeder, None
        if feeder is not None:
            feeder.close()

    def __enter__(self) -> "ServingPipeline":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        """Context-manager exit: ``close()`` -- feeder threads joined."""
        self.close()

    def _drive(self, it) -> Iterator[RequestRecord]:
        """The cycle loop behind ``serve`` (source already feeder-wrapped)."""
        resident: List[_Slot] = []
        if self.prefetch is None:
            self._pump(it, float("inf"), block=True)
        while True:
            yield from self._cull()     # expired-while-staged give-ups
            while len(resident) < self.slots:
                free = self.slots - len(resident)
                picks = self.policy.pick_many(self._groups.values(),
                                              self.clock(), free)
                if not picks:
                    self._pump(it, max(1, self.prefetch or 1)
                               + self.policy.pull_bonus())
                    picks = self.policy.pick_many(self._groups.values(),
                                                  self.clock(), free)
                    if not picks:
                        if self._staged_count():   # held by an open window
                            self.stats.admission_holds += 1
                        break
                # The packing path: fill every free slot this cycle from
                # the policy's ranked groups. The default pick_many returns
                # one group, reproducing the legacy one-admit-per-iteration
                # cadence (and its pump interleaving) exactly.
                for group in picks[:free]:
                    if group.queue:
                        resident.append(self._admit(group))
            if not resident:
                if not self._await_work(it):
                    return
                continue
            for slot in resident:
                slot.r_before = slot.rounds_host.copy()
                slot.state = self.engine.step(slot.state,
                                              chunk_rounds=self.chunk)
            if self.prefetch:
                # Host-side staging overlapped with the in-flight chunks.
                # Dead slots whose group queue is empty raise the pull
                # target: staged work from *other* groups must not stop us
                # from fetching requests that could revive them. A holding
                # policy (windowed) adds its fill deficit on top.
                hunger = sum(1 for slot in resident for rid in slot.live
                             if rid is None and not slot.group.queue)
                self._pump(it, self.prefetch + hunger
                           + self.policy.pull_bonus())
            for slot in list(resident):
                yield from self._service(slot)
                if all(rid is None for rid in slot.live):
                    resident.remove(slot)


def _materialized_plan(pgms: Sequence[PGM], growth: float):
    """Legacy-compatible plan for a fully materialized stream: group by
    ``bucket_key``, pad every member to its *group's* joint ceilings, and
    feed requests in sorted-key order -- exactly the legacy ``serve``
    policy, so trajectories (and with ``slots=1``, even sweep accounting)
    coincide."""
    keyed: Dict[tuple, List[int]] = {}
    for i, p in enumerate(pgms):
        keyed.setdefault(bucket_key(p, growth), []).append(i)
    plan, ordered = {}, []
    for key in sorted(keyed):
        idx = keyed[key]
        plan[key] = group_ceilings([pgms[i] for i in idx])
        ordered.extend((i, pgms[i]) for i in idx)
    return plan, ordered


def serve_async(engine: BPEngine, stream, rng: jax.Array, *,
                growth: float = 2.0, max_batch: int | None = None,
                chunk_rounds: int | None = None, evacuate: bool = True,
                compact: bool = True, slots: int = 2,
                prefetch: int | None = 8,
                record_events: bool = True,
                admission: "str | AdmissionPolicy | None" = None,
                admission_kwargs: Mapping | None = None,
                ingest_threads: int = 0,
                ingest_queue: int | None = None,
                clock=None) -> AsyncServeResult:
    """Serve a request stream through the asynchronous pipeline.

    ``stream`` is either a materialized ``Sequence[PGM]`` -- padded with the
    legacy group-ceiling plan, so per-request results are *bitwise
    identical* to ``BPEngine.serve`` on the same inputs -- or any iterator
    of PGMs (the online path: each request pads to its deterministic
    ``bucket_shape`` ceilings the moment it arrives, no global knowledge
    needed). Iterator items may also be ``(rid, PGM)`` pairs or
    ``(rid, PGM, slo_s)`` deadline triples -- see :class:`ServingPipeline`.
    ``admission``/``admission_kwargs`` select the admission policy
    (``"fifo"`` | ``"residual"`` | ``"windowed"`` | ``"deadline"``;
    ``None`` defers to the engine's ``BPConfig.admission``),
    ``ingest_threads``/``ingest_queue`` enable the threaded ingestion
    feeder, and ``clock`` injects a virtual time source (a
    :class:`SweepClock` makes SLA behavior deterministic) -- see
    :class:`ServingPipeline` and ``docs/admission.md``. This wrapper just
    collects the generator into an :class:`AsyncServeResult` (records in
    completion order, ``.results`` in input order)."""
    plan = None
    # Only a sequence of bare PGMs takes the materialized-plan path:
    # (rid, pgm[, slo]) tuple sequences keep their explicit rids (the plan
    # would renumber them by position) and stream online.
    if isinstance(stream, Sequence) and (
            not stream or isinstance(stream[0], PGM)):
        plan, stream = _materialized_plan(list(stream), growth)
    pipe = ServingPipeline(engine, rng, growth=growth, max_batch=max_batch,
                           chunk_rounds=chunk_rounds, evacuate=evacuate,
                           compact=compact, slots=slots, prefetch=prefetch,
                           record_events=record_events, plan=plan,
                           admission=admission,
                           admission_kwargs=admission_kwargs,
                           ingest_threads=ingest_threads,
                           ingest_queue=ingest_queue, clock=clock)
    records = list(pipe.serve(stream))
    return AsyncServeResult(records=records, stats=pipe.stats)
