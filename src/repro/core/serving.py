"""Asynchronous BP serving: online request streams, double-buffered bucket
slots, prefetch staging, and bucket compaction.

``BPEngine.serve`` (repro.core.engine) made the engine a scheduler one level
up: it decides which graphs occupy device slots each chunk. But the legacy
driver materializes the whole request list, steps one resident bucket at a
time, and keeps a bucket at its admission width until its group finishes --
once the pending queue drains, evacuated slots are dead weight every
remaining chunk still pays for. This module rebuilds that loop as a
pipeline:

- **online streams**: requests arrive from any iterator; nothing needs the
  full workload up front. Arrivals are *staged* -- padded host-side (numpy,
  no XLA warm-up) and moved early with ``jax.device_put`` -- so admission
  and backfill never wait on host prep or H2D transfer.
- **double-buffered slots**: up to ``slots`` resident buckets are stepped
  per cycle. Every slot dispatches first (JAX async dispatch returns
  before the chunk finishes), then the host pulls and stages new arrivals
  *while the device crunches*, and only then does each slot sync and get
  serviced (evacuation, backfill, compaction). Host bucketing no longer
  idles the device, and a straggling bucket no longer idles the host.
- **bucket compaction**: when a group's queue has drained and the stream is
  exhausted, survivors re-bucket into a narrower batch (power-of-two
  widths, so at most log2(width) recompiles per shape family), removing
  the dead-slot sweeps that evacuation alone cannot -- a slot with no
  pending work to backfill still costs one device sweep per loop iteration
  at the old width.

Trajectory invariance is the load-bearing property: a graph's trajectory
depends only on its own padded shape and RNG key (the batched loop body is
per-graph gated, and the update runs on a disjoint union), so neither the
slot count, nor backfill order, nor compaction changes any result bit. On a
materialized ``Sequence`` the pipeline reuses ``serve``'s group-ceiling
padding, making ``serve_async`` bitwise-identical to the legacy driver --
which is now itself a thin wrapper over this module.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, Iterable, Iterator, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batch import (BatchedPGM, _pow2_ceil, bucket_key,
                              bucket_shape, group_ceilings)
from repro.core.engine import (BPEngine, BPResult, BPState, ServeStats,
                               _load_slot)
from repro.core.graph import PGM, pad_pgm_arrays

__all__ = ["AsyncServeResult", "AsyncServeStats", "RequestRecord",
           "ServingPipeline", "serve_async"]


# --------------------------------------------------------------- records --

@dataclasses.dataclass
class RequestRecord:
    """One served request: its ``BPResult`` plus the host-side timeline.

    ``t_enqueue`` is when the request was pulled from the stream (queue-in),
    ``t_admit`` when it was loaded into a resident bucket slot, ``t_done``
    when its result was released after a chunk sync (``perf_counter``
    seconds; the result's arrays may still be materializing -- release is
    dispatch, not blocking). ``latency_s`` is the serving metric: queue-in
    to result release."""

    rid: int                    # input position (also the RNG fold_in index)
    result: BPResult
    t_enqueue: float
    t_admit: float
    t_done: float

    @property
    def latency_s(self) -> float:
        """Queue-in -> result-release latency, seconds."""
        return self.t_done - self.t_enqueue

    @property
    def queue_s(self) -> float:
        """Time spent waiting for a bucket slot, seconds."""
        return self.t_admit - self.t_enqueue

    @property
    def service_s(self) -> float:
        """Time resident in a bucket slot, seconds."""
        return self.t_done - self.t_admit


@dataclasses.dataclass
class AsyncServeStats(ServeStats):
    """``ServeStats`` plus the async pipeline's own accounting.

    ``compactions`` counts re-bucketing events (``compaction_log`` records
    ``(chunk index, width before, width after)`` for each);
    ``buckets_opened`` counts slot admissions (fresh resident batches, i.e.
    compile-relevant shapes seen), and ``staged`` counts requests pulled
    from the stream and prefetched to the device."""

    compactions: int = 0
    #: (chunk index, width before, width after) per compaction event
    compaction_log: List[Tuple[int, int, int]] = dataclasses.field(
        default_factory=list)
    buckets_opened: int = 0
    staged: int = 0


@dataclasses.dataclass
class AsyncServeResult:
    """``serve_async`` output: per-request records in *completion* order
    plus pipeline stats. ``results`` re-sorts to input (rid) order, matching
    the legacy ``ServeResult.results`` contract."""

    records: List[RequestRecord]    # completion order
    stats: AsyncServeStats

    @property
    def results(self) -> List[BPResult]:
        """Per-request ``BPResult`` list indexed by rid. For the usual
        dense 0..n-1 rids this is input order; streams that supplied sparse
        explicit rids leave ``None`` gaps at the unused positions (rejected
        beyond a small sparsity factor -- use ``.records`` there)."""
        n = 1 + max((rec.rid for rec in self.records), default=-1)
        if n > 4 * len(self.records) + 64:
            raise ValueError(
                f"rids too sparse for a dense results list (max rid {n - 1} "
                f"over {len(self.records)} records); use .records instead")
        out: List[BPResult | None] = [None] * n
        for rec in self.records:
            out[rec.rid] = rec.result
        return out  # type: ignore[return-value]

    def latency_percentiles(
            self, qs: Sequence[float] = (50, 95, 99)) -> Dict[str, float]:
        """Queue-to-result latency percentiles in ms, ``{"p50": ...}``
        (NaN entries when no requests were served)."""
        if not self.records:
            return {f"p{q:g}": float("nan") for q in qs}
        lat = np.array([r.latency_s for r in self.records]) * 1e3
        return {f"p{q:g}": float(np.percentile(lat, q)) for q in qs}


# ------------------------------------------------------------- internals --

@dataclasses.dataclass
class _Staged:
    """A request staged for admission: padded to its group's ceilings and
    already ``device_put`` (the prefetch)."""
    rid: int
    elem: PGM
    key: jax.Array
    t_enqueue: float


class _Group:
    """One shape family: fixed padded-shape ceilings + its pending queue."""

    __slots__ = ("ceilings", "queue")

    def __init__(self, ceilings: Tuple[int, int, int, int, int]):
        self.ceilings = ceilings
        self.queue: Deque[_Staged] = deque()


@dataclasses.dataclass(eq=False)     # remove-by-identity from the slot list
class _Slot:
    """One resident bucket: its group, engine state, and host-side caches
    (live rid per batch slot, last-synced per-graph rounds, admit times)."""
    group: _Group
    state: BPState
    live: List[int | None]
    rounds_host: np.ndarray
    r_before: np.ndarray
    meta: Dict[int, Tuple[float, float]]    # rid -> (t_enqueue, t_admit)

    @property
    def width(self) -> int:
        return len(self.live)


def _narrow_state(state: BPState, idx: Sequence[int]) -> BPState:
    """Gather batch slots ``idx`` out of a batched ``BPState`` (the
    compaction primitive): every per-graph leaf -- graph arrays, messages,
    scheduler carry, RNG keys, counters -- is sliced along the batch axis,
    so each kept graph's trajectory continues bit-for-bit in the narrower
    batch."""
    ia = jnp.asarray(list(idx), dtype=jnp.int32)
    take = lambda x: x[ia]                                    # noqa: E731
    return dataclasses.replace(
        state,
        graph=state.graph.take(ia),
        logm=take(state.logm),
        sched_state=jax.tree.map(take, state.sched_state),
        rng=state.rng[ia],
        rounds=take(state.rounds),
        done=take(state.done),
        updates=take(state.updates),
        unconverged_history=take(state.unconverged_history),
        max_residual=take(state.max_residual))


# --------------------------------------------------------------- pipeline --

class ServingPipeline:
    """The asynchronous serving driver (see module docstring).

    One pipeline instance serves one stream through one ``BPEngine``.
    ``serve(stream)`` is a generator yielding a ``RequestRecord`` per
    request *in completion order* -- consume it incrementally for online
    workloads, or use :func:`serve_async` to collect everything.

    Knobs: ``slots`` bounds resident buckets stepped per cycle (2 =
    double-buffering; 1 reproduces the legacy serve cadence exactly);
    ``prefetch`` is the staged-request low-water mark the host keeps pulled
    ahead of admission (``None`` = drain the stream eagerly up front);
    ``evacuate``/``compact`` toggle the straggler policies;
    ``record_events=False`` drops the per-request evacuation/compaction
    logs (counters stay), bounding host memory on indefinitely long
    streams; ``plan`` maps a ``bucket_key`` to explicit group ceilings
    (the materialized-stream compat path) -- without it each request pads
    to its own deterministic ``bucket_shape`` ceilings, the online policy.

    The stream may yield ``PGM``s (rid = arrival order) or explicit
    ``(rid, PGM)`` pairs. Per-request RNG keys are ``fold_in(rng, rid)``,
    so results are independent of every pipeline knob; only the *padded
    shape* policy (plan vs online) can alter stochastic-scheduler
    trajectories, the caveat shared with ``run_many``. The stream is pulled
    on the serving thread: a source that blocks in ``__next__`` delays
    servicing, so wrap genuinely bursty sources in their own queue.
    """

    def __init__(self, engine: BPEngine, rng: jax.Array, *,
                 growth: float = 2.0, max_batch: int | None = None,
                 chunk_rounds: int | None = None, evacuate: bool = True,
                 compact: bool = True, slots: int = 2,
                 prefetch: int | None = 8,
                 record_events: bool = True,
                 plan: Dict[tuple, tuple] | None = None):
        if engine.is_serial:
            raise NotImplementedError(
                "serving needs a frontier scheduler (srbp is host-serial)")
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if max_batch is not None and max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        cfg = engine.config
        self.engine = engine
        self.rng = rng
        self.growth = growth
        self.max_batch = max_batch
        self.chunk = (chunk_rounds or cfg.chunk_rounds
                      or max(1, cfg.max_rounds // 16))
        self.evacuate = evacuate
        self.compact = compact
        self.slots = slots
        self.prefetch = prefetch
        self.record_events = record_events
        self.plan = plan
        self.stats = AsyncServeStats()
        self._groups: Dict[tuple, _Group] = {}
        self._exhausted = False
        self._arrival = 0
        # Duplicate-rid detection only applies once the stream supplies
        # explicit (rid, PGM) pairs; auto-assigned rids are unique by
        # construction, so the common online path stores nothing per
        # request (long-lived streams must not grow host memory).
        self._explicit_rids = False
        self._seen_rids: set[int] = set()

    # -- staging (host padding + device_put prefetch) ----------------------

    def _group_for(self, pgm: PGM) -> _Group:
        if self.plan is not None:
            key = bucket_key(pgm, self.growth)
            ceilings = self.plan[key]
        else:
            key = ceilings = bucket_shape(pgm, self.growth)
        group = self._groups.get(key)
        if group is None:
            group = self._groups[key] = _Group(ceilings)
        return group

    def _stage(self, rid: int, pgm: PGM, t_enqueue: float) -> None:
        if self._explicit_rids:         # rid = RNG fold_in index: must be 1:1
            if rid in self._seen_rids:
                raise ValueError(f"duplicate request id {rid} in stream")
            self._seen_rids.add(rid)
        group = self._group_for(pgm)
        e, v, s, re_, rv = group.ceilings
        arrs = pad_pgm_arrays(pgm, n_edges=e, n_vertices=v, n_states=s)
        # The prefetch: H2D starts now, overlapped with device compute.
        elem = PGM(n_real_vertices=rv, n_real_edges=re_,
                   **jax.device_put(arrs))
        group.queue.append(_Staged(
            rid, elem, jax.random.fold_in(self.rng, rid), t_enqueue))
        self.stats.staged += 1

    def _pump(self, it: Iterator, target: float) -> None:
        """Pull requests until ``target`` are staged (or the stream ends)."""
        while (not self._exhausted
               and sum(len(g.queue) for g in self._groups.values()) < target):
            try:
                item = next(it)
            except StopIteration:
                self._exhausted = True
                return
            t = time.perf_counter()
            if isinstance(item, tuple):
                rid, pgm = item
                self._explicit_rids = True
            else:
                rid, pgm = self._arrival, item
            self._arrival += 1
            self._stage(int(rid), pgm, t)

    # -- slot lifecycle ----------------------------------------------------

    def _admit(self, group: _Group) -> _Slot:
        """Open a resident bucket from the group's queue: width =
        min(max_batch, pending), stacked from prefetched elements."""
        width = min(self.max_batch or len(group.queue), len(group.queue))
        take = [group.queue.popleft() for _ in range(width)]
        batch = BatchedPGM(pgm=jax.tree.map(
            lambda *xs: jnp.stack(xs), *[s.elem for s in take]))
        keys = jnp.stack([s.key for s in take])
        state = self.engine.init(batch, keys)
        t = time.perf_counter()
        self.stats.buckets_opened += 1
        return _Slot(group=group, state=state,
                     live=[s.rid for s in take],
                     rounds_host=np.zeros(width, np.int64),
                     r_before=np.zeros(width, np.int64),
                     meta={s.rid: (s.t_enqueue, t) for s in take})

    def _release(self, slot: _Slot, j: int) -> RequestRecord:
        rid = slot.live[j]
        assert rid is not None
        result = self.engine._slice_result(slot.state, j)
        slot.live[j] = None
        self.stats.evacuated += 1
        if self.record_events:      # O(requests) log; off for infinite streams
            self.stats.evacuation_log.append((self.stats.chunks, rid))
        t_enq, t_adm = slot.meta.pop(rid)
        return RequestRecord(rid=rid, result=result, t_enqueue=t_enq,
                             t_admit=t_adm, t_done=time.perf_counter())

    def _backfill(self, slot: _Slot, j: int) -> None:
        staged = slot.group.queue.popleft()
        slot.state = _load_slot(slot.state, jnp.int32(j), staged.elem,
                                staged.key, scheduler=self.engine.scheduler)
        slot.live[j] = staged.rid
        slot.rounds_host[j] = 0
        slot.meta[staged.rid] = (staged.t_enqueue, time.perf_counter())
        self.stats.backfilled += 1

    def _maybe_compact(self, slot: _Slot) -> None:
        """Re-bucket survivors into a narrower batch once no backfill can
        ever arrive (queue drained, stream exhausted). Pow2 target widths
        bound recompiles at log2(width) per shape family; surplus slots are
        filled with already-dead entries, which the gated chunk body keeps
        inert."""
        if not (self.compact and self.evacuate and self._exhausted
                and not slot.group.queue):
            return
        keep = [j for j, rid in enumerate(slot.live) if rid is not None]
        if not keep:
            return
        new_w = _pow2_ceil(len(keep))
        if new_w >= slot.width:
            return
        dead = [j for j, rid in enumerate(slot.live) if rid is None]
        chosen = sorted(keep + dead[:new_w - len(keep)])
        self.stats.compactions += 1
        if self.record_events:
            self.stats.compaction_log.append(
                (self.stats.chunks, slot.width, new_w))
        slot.state = _narrow_state(slot.state, chosen)
        slot.live = [slot.live[j] for j in chosen]
        slot.rounds_host = slot.rounds_host[chosen]
        slot.r_before = slot.r_before[chosen]

    def _service(self, slot: _Slot) -> Iterable[RequestRecord]:
        """Sync one stepped slot and apply the straggler policies: account
        sweeps, release finished graphs, backfill freed slots from the
        group queue, then consider compaction."""
        state = slot.state
        r_after = np.asarray(jax.device_get(state.rounds))   # blocks on chunk
        done = np.asarray(jax.device_get(state.done))
        max_rounds = self.engine.config.max_rounds
        inner = self.engine.scheduler.inner_sweeps
        self.stats.chunks += 1
        self.stats.device_sweeps += int(state.chunk_iters) * inner * slot.width
        self.stats.useful_sweeps += int(sum(
            int(r_after[j] - slot.r_before[j])
            for j in range(slot.width) if slot.live[j] is not None))
        slot.rounds_host = r_after.copy()
        if not self.evacuate:
            # Run-to-completion baseline: release everything only when the
            # whole bucket is finished; never backfill, never compact.
            if all(bool(done[j]) or r_after[j] >= max_rounds
                   for j in range(slot.width)):
                for j in range(slot.width):
                    yield self._release(slot, j)
            return
        for j in range(slot.width):
            if slot.live[j] is None:
                continue
            if bool(done[j]) or r_after[j] >= max_rounds:
                yield self._release(slot, j)
                if slot.group.queue:
                    self._backfill(slot, j)
        # Slots that went dead while the queue was momentarily empty are
        # revived by later arrivals -- without this, an online straggler
        # bucket would burn dead-slot sweeps while new same-shape requests
        # queue behind it.
        for j in range(slot.width):
            if slot.live[j] is None and slot.group.queue:
                self._backfill(slot, j)
        self._maybe_compact(slot)

    # -- the drive loop ----------------------------------------------------

    def serve(self, stream: Iterable) -> Iterator[RequestRecord]:
        """Drive ``stream`` through the pipeline, yielding one
        ``RequestRecord`` per request in completion order.

        Each cycle: (1) admit staged groups into free slots, (2) dispatch a
        chunk on every slot (JAX async dispatch -- non-blocking), (3) pull
        and stage new arrivals while the device runs, (4) sync + service
        each slot, yielding released results. Terminates when the stream is
        exhausted and every admitted graph has been released."""
        it = iter(stream)
        resident: List[_Slot] = []
        if self.prefetch is None:
            self._pump(it, float("inf"))
        # Cross-group FIFO: admit the group whose head request has waited
        # longest, so a minority shape family cannot starve behind a
        # sustained majority one.
        def oldest():
            return min((g for g in self._groups.values() if g.queue),
                       key=lambda g: g.queue[0].t_enqueue, default=None)

        while True:
            while len(resident) < self.slots:
                group = oldest()
                if group is None:
                    self._pump(it, max(1, self.prefetch or 1))
                    group = oldest()
                    if group is None:
                        break                   # stream exhausted, all staged
                resident.append(self._admit(group))
            if not resident:
                return
            for slot in resident:
                slot.r_before = slot.rounds_host.copy()
                slot.state = self.engine.step(slot.state,
                                              chunk_rounds=self.chunk)
            if self.prefetch:
                # Host-side staging overlapped with the in-flight chunks.
                # Dead slots whose group queue is empty raise the pull
                # target: staged work from *other* groups must not stop us
                # from fetching requests that could revive them.
                hunger = sum(1 for slot in resident for rid in slot.live
                             if rid is None and not slot.group.queue)
                self._pump(it, self.prefetch + hunger)
            for slot in list(resident):
                yield from self._service(slot)
                if all(rid is None for rid in slot.live):
                    resident.remove(slot)


def _materialized_plan(pgms: Sequence[PGM], growth: float):
    """Legacy-compatible plan for a fully materialized stream: group by
    ``bucket_key``, pad every member to its *group's* joint ceilings, and
    feed requests in sorted-key order -- exactly the legacy ``serve``
    policy, so trajectories (and with ``slots=1``, even sweep accounting)
    coincide."""
    keyed: Dict[tuple, List[int]] = {}
    for i, p in enumerate(pgms):
        keyed.setdefault(bucket_key(p, growth), []).append(i)
    plan, ordered = {}, []
    for key in sorted(keyed):
        idx = keyed[key]
        plan[key] = group_ceilings([pgms[i] for i in idx])
        ordered.extend((i, pgms[i]) for i in idx)
    return plan, ordered


def serve_async(engine: BPEngine, stream, rng: jax.Array, *,
                growth: float = 2.0, max_batch: int | None = None,
                chunk_rounds: int | None = None, evacuate: bool = True,
                compact: bool = True, slots: int = 2,
                prefetch: int | None = 8,
                record_events: bool = True) -> AsyncServeResult:
    """Serve a request stream through the asynchronous pipeline.

    ``stream`` is either a materialized ``Sequence[PGM]`` -- padded with the
    legacy group-ceiling plan, so per-request results are *bitwise
    identical* to ``BPEngine.serve`` on the same inputs -- or any iterator
    of PGMs (the online path: each request pads to its deterministic
    ``bucket_shape`` ceilings the moment it arrives, no global knowledge
    needed). See :class:`ServingPipeline` for the knobs; this wrapper just
    collects the generator into an :class:`AsyncServeResult` (records in
    completion order, ``.results`` in input order)."""
    plan = None
    if isinstance(stream, Sequence):
        plan, stream = _materialized_plan(list(stream), growth)
    pipe = ServingPipeline(engine, rng, growth=growth, max_batch=max_batch,
                           chunk_rounds=chunk_rounds, evacuate=evacuate,
                           compact=compact, slots=slots, prefetch=prefetch,
                           record_events=record_events, plan=plan)
    records = list(pipe.serve(stream))
    return AsyncServeResult(records=records, stats=pipe.stats)
