"""Serial Residual BP (the paper's SRBP baseline, SS III-B).

The paper implements SRBP with a Boost Fibonacci heap on a Xeon; here it is a
host-side numpy implementation with a lazy-deletion binary heap (same
asymptotics for our sizes, no external deps). One message -- the global
max-residual one -- is updated per step; residuals of the out-edges of the
destination vertex are refreshed incrementally.

This is the *speed baseline* for Tables I-III and the *quality baseline* for
Fig 5 (KL parity). It operates on the same padded ``PGM`` arrays, host-side.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import List, Optional

import numpy as np

from repro.core.graph import PGM

NEG_INF = -1.0e30


@dataclasses.dataclass
class SRBPResult:
    """Host-serial RBP baseline output: ``beliefs (V, S) float64`` log-
    marginals, the count of single-message ``updates`` executed, and
    ``converged`` -- True iff the global max residual fell below eps before
    the update/time budget ran out."""

    beliefs: np.ndarray
    updates: int
    converged: bool
    wall_time_s: float
    max_residual: float


def _np(x) -> np.ndarray:
    return np.asarray(x)


class _SerialBP:
    def __init__(self, pgm: PGM):
        self.src = _np(pgm.edge_src)
        self.dst = _np(pgm.edge_dst)
        self.rev = _np(pgm.edge_rev)
        self.emask = _np(pgm.edge_mask)
        self.log_psi_e = _np(pgm.log_psi_e).astype(np.float64)
        self.log_psi_v = _np(pgm.log_psi_v).astype(np.float64)
        self.smask = _np(pgm.state_mask)
        self.n_states = _np(pgm.n_states)
        self.V = pgm.n_vertices
        self.real_edges = np.nonzero(self.emask)[0]
        # out_edges[v] = directed edges with src == v
        self.out_edges: List[np.ndarray] = [
            np.empty(0, np.int64)] * self.V
        order = np.argsort(self.src[self.real_edges], kind="stable")
        sorted_e = self.real_edges[order]
        srcs = self.src[sorted_e]
        bounds = np.searchsorted(srcs, np.arange(self.V + 1))
        for v in range(self.V):
            self.out_edges[v] = sorted_e[bounds[v]:bounds[v + 1]]
        # uniform init
        self.logm = np.where(
            self.smask[self.dst],
            -np.log(self.n_states[self.dst].astype(np.float64))[:, None],
            NEG_INF)
        self.vsum = np.zeros((self.V, self.logm.shape[1]))
        np.add.at(self.vsum, self.dst[self.real_edges],
                  self.logm[self.real_edges])

    def candidate(self, e: int) -> np.ndarray:
        i = self.src[e]
        pre = (self.log_psi_v[i] + self.vsum[i] - self.logm[self.rev[e]])
        pre = np.where(self.smask[i], pre, NEG_INF)
        scores = self.log_psi_e[e] + pre[:, None]
        m = np.max(scores, axis=0)
        m = np.maximum(m, NEG_INF)
        cand = m + np.log(np.maximum(
            np.sum(np.exp(scores - m[None, :]), axis=0), 1e-300))
        dmask = self.smask[self.dst[e]]
        z_m = np.max(np.where(dmask, cand, NEG_INF))
        z = z_m + np.log(np.sum(np.where(dmask, np.exp(cand - z_m), 0.0)))
        return np.where(dmask, cand - z, NEG_INF)

    def residual(self, e: int, cand: Optional[np.ndarray] = None) -> float:
        if cand is None:
            cand = self.candidate(e)
        dmask = self.smask[self.dst[e]]
        return float(np.max(np.where(dmask, np.abs(cand - self.logm[e]), 0.0)))

    def commit(self, e: int, cand: np.ndarray) -> None:
        j = self.dst[e]
        self.vsum[j] = self.vsum[j] - self.logm[e] + cand
        self.logm[e] = cand

    def beliefs(self) -> np.ndarray:
        b = self.log_psi_v + self.vsum
        b = np.where(self.smask, b, NEG_INF)
        m = np.max(b, axis=1, keepdims=True)
        z = m + np.log(np.sum(np.exp(b - m), axis=1, keepdims=True))
        return np.where(self.smask, b - z, NEG_INF)


def srbp_run(pgm: PGM, *, eps: float = 1e-3,
             max_updates: int = 10_000_000,
             time_limit_s: float = 90.0) -> SRBPResult:
    """Greedy max-residual serial BP (paper gives SRBP 90 s before declaring
    non-convergence -- same default here). Reached through the unified API
    as ``BPEngine(BPConfig(scheduler="srbp", scheduler_kwargs={...})).run``.
    """
    bp = _SerialBP(pgm)
    stamp = np.zeros(bp.logm.shape[0], np.int64)
    heap: list = []
    for e in bp.real_edges:
        r = bp.residual(int(e))
        heapq.heappush(heap, (-r, int(stamp[e]), int(e)))
    t0 = time.perf_counter()
    updates = 0
    max_r = np.inf
    converged = False
    while updates < max_updates:
        if updates % 256 == 0 and time.perf_counter() - t0 > time_limit_s:
            break
        # pop until fresh
        while heap and heap[0][1] != stamp[heap[0][2]]:
            heapq.heappop(heap)
        if not heap:
            converged = True
            max_r = 0.0
            break
        neg_r, _, e = heap[0]
        max_r = -neg_r
        if max_r < eps:
            converged = True
            break
        heapq.heappop(heap)
        cand = bp.candidate(e)
        bp.commit(e, cand)
        updates += 1
        stamp[e] += 1
        heapq.heappush(heap, (0.0, int(stamp[e]), e))  # own residual now 0
        j = int(bp.dst[e])
        for e2 in bp.out_edges[j]:
            e2 = int(e2)
            r2 = bp.residual(e2)
            stamp[e2] += 1
            heapq.heappush(heap, (-r2, int(stamp[e2]), e2))
    return SRBPResult(beliefs=bp.beliefs(), updates=updates,
                      converged=converged,
                      wall_time_s=time.perf_counter() - t0,
                      max_residual=float(max_r))


def run_srbp(pgm: PGM, *, eps: float = 1e-3,
             max_updates: int = 10_000_000,
             time_limit_s: float = 90.0) -> SRBPResult:
    """Deprecated wrapper: use
    ``BPEngine(BPConfig(scheduler="srbp", eps=...,
    scheduler_kwargs={"time_limit_s": ...})).run(pgm)``."""
    import warnings
    warnings.warn(
        "run_srbp is deprecated: use repro.core.BPEngine with "
        "BPConfig(scheduler='srbp')", DeprecationWarning, stacklevel=2)
    return srbp_run(pgm, eps=eps, max_updates=max_updates,
                    time_limit_s=time_limit_s)
