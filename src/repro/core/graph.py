"""Pairwise discrete MRF representation for many-core Belief Propagation.

The paper (Van der Merwe et al., 2019) stores the PGM as an adjacency list
with per-edge/vertex IDs assigned to CUDA threads. The TPU/XLA analogue is a
*static-shape, padded, structure-of-arrays* layout:

- every undirected edge {i, j} becomes two *directed* edges (i->j), (j->i);
  message ``m[e]`` lives on directed edge ``e``,
- ``edge_rev[e]`` gives the index of the opposing directed edge (needed to
  exclude ``m_{j->i}`` when computing ``m_{i->j}``),
- vertices may have heterogeneous state counts (protein-folding graphs range
  2..81); everything is padded to ``n_states`` with masked ``-NEG_INF``
  potentials,
- edge and vertex arrays are padded to lane-friendly multiples so the Pallas
  kernel can put the edge dimension on the 128-wide lane axis.

All arrays are plain ``jnp`` arrays registered as a pytree so a ``PGM`` can be
passed through ``jax.jit`` / ``shard_map`` unchanged.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

# Large-negative stand-in for log(0). Chosen so that summing ~1e2 of them in
# float32 stays far from -inf/NaN territory while exp() underflows to exactly 0.
NEG_INF = -1.0e30

# Edge-count padding multiple. 128 = TPU lane width; the Pallas message kernel
# tiles edges along lanes.
EDGE_PAD = 128
VERTEX_PAD = 8


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PGM:
    """Padded, directed-edge MRF.

    Shapes (E = padded directed-edge count, V = padded vertex count + 1 dummy,
    S = padded state count):
      edge_src, edge_dst, edge_rev : (E,)  int32
      edge_mask                    : (E,)  bool    True for real edges
      log_psi_e                    : (E, S, S) f32  [x_src, x_dst]
      log_psi_v                    : (V, S) f32     NEG_INF at invalid states
      state_mask                   : (V, S) bool
      n_states                     : (V,)  int32
    """

    edge_src: jax.Array
    edge_dst: jax.Array
    edge_rev: jax.Array
    edge_mask: jax.Array
    log_psi_e: jax.Array
    log_psi_v: jax.Array
    state_mask: jax.Array
    n_states: jax.Array
    # Static metadata (ints, not traced). Under batching these hold the
    # *bucket ceiling* (max real count over the batch) so every graph in a
    # bucket shares one treedef; the traced per-graph counts live below.
    n_real_vertices: int = dataclasses.field(metadata=dict(static=True))
    n_real_edges: int = dataclasses.field(metadata=dict(static=True))  # directed
    # Traced real counts, () int32. Schedulers must size frontiers from these
    # (via ``traced_edge_count``/``traced_vertex_count``) so the same trace
    # serves every graph of a vmapped bucket. ``None`` falls back to the
    # static ints for hand-built PGMs.
    edge_count: jax.Array | None = None
    vertex_count: jax.Array | None = None

    @property
    def n_edges(self) -> int:
        """Padded directed edge count."""
        return self.edge_src.shape[0]

    @property
    def n_vertices(self) -> int:
        """Padded vertex count (includes 1 dummy sink vertex)."""
        return self.log_psi_v.shape[0]

    @property
    def n_states_max(self) -> int:
        return self.log_psi_v.shape[1]

    def traced_edge_count(self) -> jax.Array:
        """() int32 real directed-edge count, traced (batch-safe)."""
        if self.edge_count is None:
            return jnp.int32(self.n_real_edges)
        return self.edge_count

    def traced_vertex_count(self) -> jax.Array:
        """() int32 real vertex count, traced (batch-safe)."""
        if self.vertex_count is None:
            return jnp.int32(self.n_real_vertices)
        return self.vertex_count

    def degree(self) -> jax.Array:
        """In-degree per vertex (== out-degree; graph is symmetric)."""
        return jax.ops.segment_sum(
            self.edge_mask.astype(jnp.int32), self.edge_dst,
            num_segments=self.n_vertices)


def build_pgm_uniform(
    n_vertices: int,
    edges: np.ndarray,          # (E_und, 2)
    unary: np.ndarray,          # (V, S) linear-space
    pairwise: np.ndarray,       # (E_und, S, S) linear-space
    *,
    edge_pad: int = EDGE_PAD,
    dtype=jnp.float32,
) -> PGM:
    """Vectorized builder for uniform state-count graphs (Ising/chain at any
    scale -- the python-loop path in ``build_pgm`` is O(E) interpreter time).
    """
    edges = np.asarray(edges, dtype=np.int64)
    e_und = edges.shape[0]
    e_dir = 2 * e_und
    s = unary.shape[1]
    e_pad = _round_up(max(e_dir, 1), edge_pad)
    v_pad = _round_up(n_vertices + 1, VERTEX_PAD)
    dummy = n_vertices

    edge_src = np.full((e_pad,), dummy, dtype=np.int32)
    edge_dst = np.full((e_pad,), dummy, dtype=np.int32)
    edge_rev = np.arange(e_pad, dtype=np.int32)
    edge_mask = np.zeros((e_pad,), dtype=bool)
    log_psi_e = np.zeros((e_pad, s, s), dtype=np.float32)
    log_psi_v = np.full((v_pad, s), NEG_INF, dtype=np.float32)
    state_mask = np.zeros((v_pad, s), dtype=bool)
    n_states = np.full((v_pad,), 1, dtype=np.int32)

    fwd = np.arange(0, e_dir, 2)
    bwd = fwd + 1
    edge_src[fwd], edge_dst[fwd] = edges[:, 0], edges[:, 1]
    edge_src[bwd], edge_dst[bwd] = edges[:, 1], edges[:, 0]
    edge_rev[fwd], edge_rev[bwd] = bwd, fwd
    edge_mask[:e_dir] = True
    lp = np.log(pairwise.astype(np.float64)).astype(np.float32)
    log_psi_e[fwd] = lp
    log_psi_e[bwd] = np.swapaxes(lp, 1, 2)
    log_psi_v[:n_vertices] = np.log(unary.astype(np.float64))
    state_mask[:n_vertices] = True
    n_states[:n_vertices] = s
    log_psi_v[dummy:, 0] = 0.0
    state_mask[dummy:, 0] = True

    return PGM(
        edge_src=jnp.asarray(edge_src), edge_dst=jnp.asarray(edge_dst),
        edge_rev=jnp.asarray(edge_rev), edge_mask=jnp.asarray(edge_mask),
        log_psi_e=jnp.asarray(log_psi_e, dtype=dtype),
        log_psi_v=jnp.asarray(log_psi_v, dtype=dtype),
        state_mask=jnp.asarray(state_mask), n_states=jnp.asarray(n_states),
        n_real_vertices=n_vertices, n_real_edges=e_dir,
        edge_count=jnp.int32(e_dir), vertex_count=jnp.int32(n_vertices))


def build_pgm(
    n_vertices: int,
    edges: np.ndarray,              # (E_und, 2) int, undirected vertex pairs
    unary: Sequence[np.ndarray],    # per-vertex (S_i,) potentials, linear space
    pairwise: Sequence[np.ndarray],  # per-undirected-edge (S_i, S_j), linear
    *,
    edge_pad: int = EDGE_PAD,
    state_pad_to: int | None = None,
    dtype=jnp.float32,
) -> PGM:
    """Build a padded PGM from host-side numpy potentials (linear space).

    Potentials must be strictly positive (MRF definition, psi: -> R+).
    """
    edges = np.asarray(edges, dtype=np.int64)
    assert edges.ndim == 2 and edges.shape[1] == 2
    e_und = edges.shape[0]
    e_dir = 2 * e_und

    n_states_arr = np.array([len(u) for u in unary], dtype=np.int32)
    s_max = int(n_states_arr.max()) if len(unary) else 1
    if state_pad_to is not None:
        s_max = max(s_max, state_pad_to)

    e_pad = _round_up(max(e_dir, 1), edge_pad)
    v_pad = _round_up(n_vertices + 1, VERTEX_PAD)  # +1 dummy sink vertex
    dummy = n_vertices  # padded edges point at the dummy vertex

    edge_src = np.full((e_pad,), dummy, dtype=np.int32)
    edge_dst = np.full((e_pad,), dummy, dtype=np.int32)
    edge_rev = np.arange(e_pad, dtype=np.int32)  # padded edges self-reverse
    edge_mask = np.zeros((e_pad,), dtype=bool)
    log_psi_e = np.zeros((e_pad, s_max, s_max), dtype=np.float32)
    log_psi_v = np.full((v_pad, s_max), NEG_INF, dtype=np.float32)
    state_mask = np.zeros((v_pad, s_max), dtype=bool)
    n_states = np.ones((v_pad,), dtype=np.int32)

    for v in range(n_vertices):
        s = int(n_states_arr[v])
        u = np.asarray(unary[v], dtype=np.float64)
        assert u.shape == (s,) and np.all(u > 0), f"bad unary at vertex {v}"
        log_psi_v[v, :s] = np.log(u)
        state_mask[v, :s] = True
        n_states[v] = s
    # Dummy vertex: single valid state with psi=1 so padded edges stay inert.
    log_psi_v[dummy:, 0] = 0.0
    state_mask[dummy:, 0] = True

    for k in range(e_und):
        i, j = int(edges[k, 0]), int(edges[k, 1])
        si, sj = int(n_states_arr[i]), int(n_states_arr[j])
        p = np.asarray(pairwise[k], dtype=np.float64)
        assert p.shape == (si, sj) and np.all(p > 0), f"bad pairwise at edge {k}"
        fwd, bwd = 2 * k, 2 * k + 1
        edge_src[fwd], edge_dst[fwd] = i, j
        edge_src[bwd], edge_dst[bwd] = j, i
        edge_rev[fwd], edge_rev[bwd] = bwd, fwd
        edge_mask[fwd] = edge_mask[bwd] = True
        lp = np.log(p)
        log_psi_e[fwd, :si, :sj] = lp
        log_psi_e[bwd, :sj, :si] = lp.T

    return PGM(
        edge_src=jnp.asarray(edge_src),
        edge_dst=jnp.asarray(edge_dst),
        edge_rev=jnp.asarray(edge_rev),
        edge_mask=jnp.asarray(edge_mask),
        log_psi_e=jnp.asarray(log_psi_e, dtype=dtype),
        log_psi_v=jnp.asarray(log_psi_v, dtype=dtype),
        state_mask=jnp.asarray(state_mask),
        n_states=jnp.asarray(n_states),
        n_real_vertices=n_vertices,
        n_real_edges=e_dir,
        edge_count=jnp.int32(e_dir),
        vertex_count=jnp.int32(n_vertices),
    )


def pad_pgm_arrays(pgm: PGM, *, n_edges: int, n_vertices: int,
                   n_states: int) -> dict:
    """Host-side (numpy) re-padding of a PGM's arrays to larger shapes.

    Deliberately numpy: bucketing pads many graphs of *distinct* shapes, and
    doing it in jnp costs one tiny XLA compilation per (op, shape) pair --
    seconds of hidden warm-up per fresh request stream. Returns a field
    dict; ``pad_pgm``/``BatchedPGM.from_pgms`` convert to device arrays
    once at the end.
    """
    e0, v0, s0 = pgm.n_edges, pgm.n_vertices, pgm.n_states_max
    assert n_edges >= e0 and n_vertices >= v0 and n_states >= s0, \
        f"cannot shrink ({e0},{v0},{s0}) -> ({n_edges},{n_vertices},{n_states})"
    de, dv, ds = n_edges - e0, n_vertices - v0, n_states - s0
    dummy = pgm.n_real_vertices

    log_psi_v = np.pad(np.asarray(pgm.log_psi_v), ((0, dv), (0, ds)),
                       constant_values=NEG_INF)
    state_mask = np.pad(np.asarray(pgm.state_mask), ((0, dv), (0, ds)))
    if dv:
        # new padding vertices: one valid zero-potential state (like dummy)
        log_psi_v[v0:, 0] = 0.0
        state_mask[v0:, 0] = True
    return dict(
        edge_src=np.pad(np.asarray(pgm.edge_src), (0, de),
                        constant_values=dummy),
        edge_dst=np.pad(np.asarray(pgm.edge_dst), (0, de),
                        constant_values=dummy),
        edge_rev=np.concatenate([np.asarray(pgm.edge_rev),
                                 np.arange(e0, n_edges, dtype=np.int32)]),
        edge_mask=np.pad(np.asarray(pgm.edge_mask), (0, de)),
        log_psi_e=np.pad(np.asarray(pgm.log_psi_e),
                         ((0, de), (0, ds), (0, ds))),
        log_psi_v=log_psi_v,
        state_mask=state_mask,
        n_states=np.pad(np.asarray(pgm.n_states), (0, dv),
                        constant_values=1),
        edge_count=np.int32(pgm.n_real_edges),
        vertex_count=np.int32(pgm.n_real_vertices),
    )


def pad_pgm(pgm: PGM, *, n_edges: int, n_vertices: int, n_states: int,
            n_real_edges: int | None = None,
            n_real_vertices: int | None = None) -> PGM:
    """Re-pad a PGM to larger shared shapes (the bucketing primitive).

    Extra edges point at the graph's own dummy vertex with ``edge_mask``
    False; extra vertices get a single valid zero-potential state; extra
    state columns are masked out -- all inert by the same conventions the
    builders use, so BP on the padded graph commits the same messages on
    real edges. The optional ``n_real_*`` override the *static* metadata to
    a bucket ceiling (shared treedef across a batch); the traced per-graph
    counts are preserved.
    """
    arrs = pad_pgm_arrays(pgm, n_edges=n_edges, n_vertices=n_vertices,
                          n_states=n_states)
    return PGM(
        n_real_vertices=(pgm.n_real_vertices if n_real_vertices is None
                         else n_real_vertices),
        n_real_edges=(pgm.n_real_edges if n_real_edges is None
                      else n_real_edges),
        **{k: jnp.asarray(v) for k, v in arrs.items()},
    )
