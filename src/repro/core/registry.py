"""Shared name->entry registry: one surface for every pluggable family.

Three subsystems are addressable by string spec so that ``BPConfig`` stays
JSON-serializable end-to-end: schedulers (``repro.core.schedulers``), update
backends (``repro.kernels.ops``) and admission policies
(``repro.core.serving``). They historically grew three ad-hoc dicts with
three slightly different lookup/error conventions; :class:`Registry` is the
one implementation behind all of them:

- keys are canonical **lowercase** names (the serialized form),
- missing names raise the **uniform error format**
  ``KeyError("unknown <kind> <name>; registered: [...]")`` so callers and
  tests can rely on one message shape across families,
- duplicate registration raises ``ValueError`` (silent overwrite hid typos
  and shadowed built-ins; pass ``overwrite=True`` to replace deliberately),
- ``names()`` is the sorted listing behind the ``list_schedulers()`` /
  ``list_backends()`` / ``list_admission_policies()`` module functions, so
  CLI ``choices=`` and docs can't drift from what is actually registered.

``Registry`` subclasses ``dict``, so the pre-existing module-level names
(``SCHEDULERS``, ``UPDATE_BACKENDS``, ``ADMISSION_POLICIES``) remain
importable and behave as the plain dicts they always were -- ``in``,
``sorted(...)``, indexing, ``.items()``, ``.pop()`` all keep working -- while
gaining the uniform ``lookup``/``add``/``register``/``names`` surface.
"""

from __future__ import annotations

from typing import Callable, Dict, Iterable, List, Mapping, TypeVar

T = TypeVar("T")

__all__ = ["Registry"]


class Registry(Dict[str, T]):
    """A named ``dict`` of string spec -> registered entry (class/factory).

    ``kind`` names the family ("scheduler", "update backend", ...) and is
    interpolated into the uniform ``KeyError`` every registry raises for
    unknown names: ``unknown <kind> <name>; registered: [...]``. Keys are
    lowercased on the way in (``add``/``register``) and on the way out
    (``lookup``), so the canonical serialized form is always lowercase.
    """

    def __init__(self, kind: str,
                 initial: Mapping[str, T] | Iterable = ()) -> None:
        super().__init__({str(k).lower(): v
                          for k, v in dict(initial).items()})
        self.kind = kind

    def lookup(self, name: str) -> T:
        """Resolve ``name`` (case-insensitive) to its registered entry.

        Raises the family's uniform error for unknown names:
        ``KeyError("unknown <kind> <name>; registered: [...]")``.
        """
        try:
            return self[str(name).lower()]
        except KeyError:
            raise KeyError(self.unknown(name)) from None

    def unknown(self, name) -> str:
        """The uniform unknown-name message for this family (also used by
        callers that reject a *known but unsupported* name subset, e.g. the
        banded runner, so every error reads the same)."""
        return f"unknown {self.kind} {name!r}; registered: {self.names()}"

    def names(self) -> List[str]:
        """Sorted registered names -- the ``list_*()`` implementation."""
        return sorted(self)

    def add(self, name: str, entry: T, *, overwrite: bool = False) -> T:
        """Register ``entry`` under ``name`` (lowercased); returns it.

        Duplicate names raise ``ValueError`` unless ``overwrite=True`` --
        a silent overwrite would shadow a built-in behind the same spec
        string every serialized config resolves through.
        """
        key = str(name).lower()
        if not overwrite and key in self:
            raise ValueError(
                f"duplicate {self.kind} {name!r}: already registered "
                f"(pass overwrite=True to replace)")
        self[key] = entry
        return entry

    def register(self, name: str, *,
                 overwrite: bool = False) -> Callable[[T], T]:
        """Decorator form of :meth:`add`:

            @REGISTRY.register("mine")
            class Mine: ...
        """
        def deco(entry: T) -> T:
            return self.add(name, entry, overwrite=overwrite)
        return deco
