"""Core Belief Propagation library -- the paper's contribution.

Public API:
  build_pgm          padded pairwise-MRF builder
  run_bp             frontier-based BP (Algorithm 1) under jit
  LBP/RBP/RS/RnBP    message schedulings (Table IV)
  BatchedPGM, bucket_pgms, run_bp_batch, run_bp_many
                     batched multi-graph engine (vmap-able buckets)
  run_srbp           serial residual BP baseline
  ve_marginals, brute_force_marginals, kl_divergence   exact oracles
"""

from repro.core.graph import PGM, build_pgm, pad_pgm, NEG_INF
from repro.core.runner import BPResult, run_bp
from repro.core.batch import (BatchedPGM, Bucket, batch_keys, bucket_pgms,
                              run_bp_batch, run_bp_many)
from repro.core.schedulers import LBP, RBP, RS, RnBP
from repro.core.serial import SRBPResult, run_srbp
from repro.core.exact import (brute_force_marginals, kl_divergence,
                              ve_marginals)
from repro.core import messages

__all__ = [
    "PGM", "build_pgm", "pad_pgm", "NEG_INF", "BPResult", "run_bp",
    "BatchedPGM", "Bucket", "batch_keys", "bucket_pgms", "run_bp_batch",
    "run_bp_many",
    "LBP", "RBP", "RS", "RnBP", "SRBPResult", "run_srbp",
    "brute_force_marginals", "kl_divergence", "ve_marginals", "messages",
]
