"""Core Belief Propagation library -- the paper's contribution.

Public API (the unified engine):
  BPConfig           frozen, serializable inference config (scheduler spec,
                     eps, max_rounds, damping, backend, chunk_rounds)
  BPEngine           init/step (chunked resume), run/run_many (one-shot),
                     serve (evacuating bucketed serving driver)
  BPState            resumable trajectory state (a checkpointable pytree)
  ServeResult/ServeStats   serving output + sweep accounting
  serve_async        asynchronous serving pipeline (repro.core.serving):
                     online request iterators, double-buffered bucket
                     slots, prefetch staging, bucket compaction,
                     pluggable admission, threaded ingestion
  ServingPipeline    the pipeline driver behind serve_async (generator API)
  AdmissionPolicy    admission-policy base + registry (fifo/residual/
                     windowed/deadline via get_admission_policy);
                     DeadlineAdmission is the SLA tier -- per-request
                     deadlines, slack-ordered admission, slot packing,
                     mid-flight eviction (SweepClock for virtual time)
  get_scheduler      registry: "lbp"/"rbp"/"rs"/"rnbp"/"rlx"/"rlxtree"
                     -> Scheduler
  Registry           the shared name->entry registry class behind the
                     scheduler / update-backend / admission families;
                     list_schedulers / list_backends /
                     list_admission_policies enumerate them

Building blocks:
  build_pgm          padded pairwise-MRF builder
  LBP/RBP/RS/RnBP    message schedulings (Table IV)
  RLX/RLXTree        relaxed multi-queue priority family (2002.11505)
  BatchedPGM, bucket_pgms   vmap-able padded buckets
  ve_marginals, brute_force_marginals, kl_divergence   exact oracles

Deprecated compatibility wrappers (delegate to BPEngine, exact parity):
  run_bp, run_bp_batch, run_bp_many, run_srbp
"""

from repro.core.graph import PGM, build_pgm, pad_pgm, NEG_INF
from repro.core.registry import Registry
from repro.core.engine import (BPConfig, BPEngine, BPResult, BPState,
                               ServeResult, ServeStats)
from repro.core.serving import (ADMISSION_POLICIES, AdmissionPolicy,
                                AsyncServeResult, AsyncServeStats,
                                DeadlineAdmission, FIFOAdmission,
                                RequestRecord, ResidualAdmission,
                                ServingPipeline, SweepClock,
                                WindowedAdmission, get_admission_policy,
                                list_admission_policies,
                                register_admission_policy, serve_async)
from repro.core.runner import run_bp
from repro.core.batch import (BatchedPGM, Bucket, RidgeEffort,
                              RoundsHistory, batch_keys, bucket_key,
                              bucket_pgms, group_ceilings,
                              run_bp_batch, run_bp_many)
from repro.core.schedulers import (LBP, RBP, RLX, RLXTree, RS, RnBP,
                                   SCHEDULERS, get_scheduler,
                                   list_schedulers, register_scheduler,
                                   scheduler_spec)
from repro.kernels.ops import list_backends
from repro.core.serial import SRBPResult, run_srbp, srbp_run
from repro.core.exact import (brute_force_marginals, kl_divergence,
                              ve_marginals)
from repro.core import messages

__all__ = [
    "PGM", "build_pgm", "pad_pgm", "NEG_INF",
    "BPConfig", "BPEngine", "BPResult", "BPState",
    "ServeResult", "ServeStats",
    "AsyncServeResult", "AsyncServeStats", "RequestRecord",
    "ServingPipeline", "serve_async",
    "ADMISSION_POLICIES", "AdmissionPolicy", "DeadlineAdmission",
    "FIFOAdmission", "ResidualAdmission", "SweepClock",
    "WindowedAdmission", "get_admission_policy",
    "register_admission_policy",
    "Registry", "list_schedulers", "list_backends",
    "list_admission_policies",
    "BatchedPGM", "Bucket", "RidgeEffort", "RoundsHistory", "batch_keys",
    "bucket_key", "bucket_pgms", "group_ceilings",
    "LBP", "RBP", "RS", "RnBP", "RLX", "RLXTree", "SCHEDULERS",
    "get_scheduler", "register_scheduler", "scheduler_spec",
    "SRBPResult", "srbp_run",
    "brute_force_marginals", "kl_divergence", "ve_marginals", "messages",
    # deprecated wrappers
    "run_bp", "run_bp_batch", "run_bp_many", "run_srbp",
]
