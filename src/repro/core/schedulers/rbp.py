"""Residual BP, bulk-parallel sort-and-select variant (paper SS III-A).

Per round, the k = max(1, p * 2|E|) highest-residual messages form the
frontier. The paper implements this with a CUB radix key-value sort; the
XLA-native equivalent is ``lax.top_k`` (still the round's dominant cost on
both GPU and TPU -- reproducing the paper's overhead diagnosis). Ties at the
k-th residual are all admitted (threshold semantics), which keeps shapes
static without a scatter.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.graph import PGM


@dataclasses.dataclass(frozen=True)
class RBP:
    """Residual BP, bulk sort-and-select: top-k residual edges per round.

    ``select`` returns the ``k = max(1, p * 2|E|)`` highest-residual real
    edges as the ``(E,) bool`` frontier (ties at the k-th residual all
    admitted; the ``lax.top_k`` is the round's dominant cost -- the paper's
    overhead diagnosis). Deterministic given residuals; no carried state.
    Strong prioritization, poor parallel occupancy. Registry spec ``"rbp"``.
    """

    p: float = 1.0 / 256.0   # frontier multiplier: k = p * 2|E| (paper SS III-D)
    inner_sweeps: int = 1

    def init(self, pgm: PGM):
        return ()

    def select(self, pgm: PGM, residuals: jax.Array, eps: float,
               rng: jax.Array, state, unconverged: jax.Array):
        # Static k ceiling (bucket max under batching; == the graph's own k
        # for a single graph), then the per-graph k indexes into the sorted
        # top-k so one trace serves every graph of a vmapped bucket.
        k_max = max(1, int(round(self.p * pgm.n_real_edges)))
        k_max = min(k_max, residuals.shape[0])
        topk = jax.lax.top_k(residuals, k_max)[0]
        k = jnp.clip(jnp.round(self.p * pgm.traced_edge_count()
                               .astype(jnp.float32)).astype(jnp.int32),
                     1, k_max)
        thresh = topk[k - 1]
        # Only update messages that would actually move (residual > 0); on the
        # last stretch the k-th residual is 0 and we must not thrash padding.
        frontier = (residuals >= jnp.maximum(thresh, 1e-30)) & pgm.edge_mask
        return frontier, state
