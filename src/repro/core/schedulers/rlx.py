"""Relaxed multi-queue residual BP (Aksenov, Alistarh, Korhonen 2020).

RBP's exact top-k is the round's dominant cost *and* its last global sync:
``lax.top_k`` over all E residuals is a device-wide sort, and under the
sharded backend a cross-shard gather. The relaxed-scheduling result
(arxiv 2002.11505) is that BP does not need the exact top-k: pick
*approximately* the highest-residual messages -- a MultiQueue -- and the
trajectory converges like exact residual BP while the selection becomes
embarrassingly parallel.

The bulk-parallel realization here: the edge axis is cut into ``Q``
equal contiguous queues (a static ``reshape``; contiguous blocks align with
how the sharded backend slices the edge axis, so every queue lives on one
shard when ``Q`` is a multiple of the mesh size). Each round:

1. sample a Bernoulli(``sample``) subset of queues (one tiny ``(Q,)`` draw;
   the queue holding the current max residual is always included so a
   round can never select nothing while unconverged),
2. inside each sampled queue admit the local top ``k = p * |E| / Q``
   residuals (threshold semantics like RBP), with the per-queue k-th value
   found by **bisection on the threshold** (count >= k), not by
   ``lax.top_k``: top_k lowers to a sort/TopK custom call that GSPMD
   cannot partition -- the compiler responds by all-gathering the full
   residual array, silently reintroducing the global gather this family
   exists to remove. Bisection uses only elementwise compares and
   trailing-axis count reductions, which shard cleanly along the queue
   axis.

Net: the only cross-shard traffic left in a sharded round is the update's
(V, S) psum plus O(Q)-scalar reductions -- no collective ever touches an
edge-sized array (audited from the compiled HLO by
``benchmarks/bench_tradeoff.py``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.graph import PGM


def queue_count(n_edges: int, queues: int) -> int:
    """Effective queue count: the largest ``q <= queues`` dividing the
    (static, padded) edge count, so the queue partition is an exact
    ``reshape``. Padded edge counts are multiples of ``EDGE_PAD = 128``, so
    any power-of-two ``queues <= 128`` is returned unchanged for
    builder-made graphs; odd hand-made shapes degrade gracefully (worst
    case ``q = 1`` == exact RBP semantics)."""
    q = max(1, min(int(queues), int(n_edges)))
    while n_edges % q:
        q -= 1
    return q


def queue_threshold(res2: jax.Array, k, iters: int = 30) -> jax.Array:
    """Per-queue k-th-largest threshold by bisection: the largest ``t``
    (per queue, up to float resolution) with ``count(res >= t) >= k``.

    Sort-free on purpose (see module docstring): each iteration is one
    elementwise compare plus a trailing-axis count, so a queue axis sharded
    over devices stays shard-local -- GSPMD has no sort/TopK to gather
    for. ``iters=30`` resolves the threshold to ``max_residual * 2**-30``,
    far below the eps scales BP runs at; threshold selection admits ties
    exactly like RBP's ``>= topk[k-1]`` rule. Invariant: ``lo`` always
    satisfies the count, ``hi`` never does.
    """
    hi = jnp.max(res2, axis=1) * (1.0 + 1e-6) + 1e-30       # count(>=hi) == 0
    lo = jnp.zeros_like(hi)                                 # count(>=0) == L

    def body(_, c):
        lo, hi = c
        mid = 0.5 * (lo + hi)
        ok = jnp.sum(res2 >= mid[:, None], axis=1) >= k
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)

    lo, _ = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return lo


def relaxed_frontier(res2: jax.Array, k, sample: float,
                     rng: jax.Array) -> jax.Array:
    """Shared relaxed selection core: per-queue top-k over sampled queues.

    ``res2`` is the ``(Q, L)`` queue view of the masked residuals (zeros on
    non-real edges); ``k`` the (possibly traced) per-queue frontier size.
    Returns the ``(Q, L)`` bool frontier: edges at or above their queue's
    k-th residual (bisection threshold, ties admitted), in queues kept by
    the Bernoulli(``sample``) draw -- the queue holding the global max
    residual is always kept, so the frontier is non-empty whenever any
    residual is. All per-queue work runs on the trailing axis only; the
    sole cross-queue reductions are the ``(Q,)`` argmax of the per-queue
    maxima and the threshold counts -- O(Q) scalars, never edge-sized data.
    """
    maxq = jnp.max(res2, axis=1)                      # (Q,) per-queue maxima
    thresh = queue_threshold(res2, k)
    keep = jax.random.uniform(rng, (res2.shape[0],)) < sample
    keep = keep.at[jnp.argmax(maxq)].set(True)        # max queue always in
    # >= max(thresh, tiny): never thrash zero-residual (converged/padding)
    # edges on the last stretch -- RBP's guard, per queue.
    return (res2 >= jnp.maximum(thresh, 1e-30)[:, None]) & keep[:, None]


@dataclasses.dataclass(frozen=True)
class RLX:
    """Relaxed multi-queue residual BP: per-queue top-k of a sampled queue
    subset -- approximate prioritization without a global sort.

    ``select`` cuts the edge axis into ``queues`` contiguous equal blocks
    (static reshape), keeps a Bernoulli(``sample``) subset of queues (the
    queue holding the max residual always included), and admits each kept
    queue's local top ``k = p * |E| / Q`` residuals (threshold semantics,
    like RBP). Stochastic: consumes one tiny ``(Q,)`` uniform draw per
    round; no carried state. Under ``backend="sharded"`` the per-queue
    sorts stay shard-local, removing RBP's cross-shard top-k gather -- the
    sharded path's last global sync. Registry spec ``"rlx"``.
    """

    queues: int = 8          # Q: relaxation degree (queues to cut edges into)
    sample: float = 0.5      # fraction of queues admitted per round
    p: float = 1.0 / 256.0   # frontier multiplier: k_per_queue = p * |E| / Q
    inner_sweeps: int = 1

    def __post_init__(self):
        if self.queues < 1:
            raise ValueError(f"queues must be >= 1, got {self.queues}")
        if not 0.0 < self.sample <= 1.0:
            raise ValueError(f"sample must be in (0, 1], got {self.sample}")
        if not self.p > 0.0:
            raise ValueError(f"p must be > 0, got {self.p}")

    def init(self, pgm: PGM):
        return ()

    def select(self, pgm: PGM, residuals: jax.Array, eps: float,
               rng: jax.Array, state, unconverged: jax.Array):
        e = residuals.shape[0]
        q = queue_count(e, self.queues)
        # Traced per-graph k (batch-safe: one trace serves every graph of a
        # vmapped bucket; the bisection threshold takes k as data).
        k = jnp.clip(jnp.round(self.p * pgm.traced_edge_count()
                               .astype(jnp.float32) / q).astype(jnp.int32),
                     1, e // q)
        res2 = jnp.where(pgm.edge_mask, residuals, 0.0).reshape(q, e // q)
        frontier = relaxed_frontier(res2, k, self.sample, rng)
        return frontier.reshape(e) & pgm.edge_mask, state
