"""Loopy (Synchronous) BP: every message, every round (paper SS II-B)."""

from __future__ import annotations

import dataclasses

import jax

from repro.core.graph import PGM


@dataclasses.dataclass(frozen=True)
class LBP:
    inner_sweeps: int = 1

    def init(self, pgm: PGM):
        return ()

    def select(self, pgm: PGM, residuals: jax.Array, eps: float,
               rng: jax.Array, state, unconverged: jax.Array):
        return pgm.edge_mask, state
