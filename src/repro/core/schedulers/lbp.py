"""Loopy (Synchronous) BP: every message, every round (paper SS II-B)."""

from __future__ import annotations

import dataclasses

import jax

from repro.core.graph import PGM


@dataclasses.dataclass(frozen=True)
class LBP:
    """Loopy (synchronous) BP: the frontier is every real edge, every round.

    ``select`` returns ``(frontier (E,) bool = edge_mask, state)`` -- no
    carried state, no RNG consumed, so trajectories are deterministic.
    Maximum parallelism per sweep but no prioritization: converges fast on
    easy graphs and may oscillate forever on hard ones (paper Fig 4).
    Registry spec ``"lbp"``.
    """

    inner_sweeps: int = 1

    def init(self, pgm: PGM):
        return ()

    def select(self, pgm: PGM, residuals: jax.Array, eps: float,
               rng: jax.Array, state, unconverged: jax.Array):
        return pgm.edge_mask, state
