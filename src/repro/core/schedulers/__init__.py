"""Message schedulings studied in the paper (Table IV).

| Algorithm  | Frontier selection            | Module   |
|------------|-------------------------------|----------|
| LBP        | all messages                  | lbp.py   |
| RBP        | sort-and-select top-k (edges) | rbp.py   |
| RS         | top-k vertices + depth-h splash | rs.py  |
| RnBP       | eps-filter + randomized p     | rnbp.py  | (paper's contribution)

Serial RBP (the paper's SRBP baseline, Boost Fibonacci-heap) lives in
``repro.core.serial`` as a host-side numpy implementation.
"""

from repro.core.schedulers.base import Scheduler
from repro.core.schedulers.lbp import LBP
from repro.core.schedulers.rbp import RBP
from repro.core.schedulers.rs import RS
from repro.core.schedulers.rnbp import RnBP

__all__ = ["Scheduler", "LBP", "RBP", "RS", "RnBP"]
