"""Message schedulings studied in the paper (Table IV) plus the relaxed
priority family (arxiv 2002.11505 / 1206.5291).

| Algorithm  | Frontier selection              | Module     | Spec      |
|------------|---------------------------------|------------|-----------|
| LBP        | all messages                    | lbp.py     | "lbp"     |
| RBP        | sort-and-select top-k (edges)   | rbp.py     | "rbp"     |
| RS         | top-k vertices + depth-h splash | rs.py      | "rs"      |
| RnBP       | eps-filter + randomized p       | rnbp.py    | "rnbp"    | (paper's contribution)
| RLX        | per-queue top-k, sampled queues | rlx.py     | "rlx"     |
| RLXTree    | rlx with dst-ordered queues     | rlxtree.py | "rlxtree" |

Schedulers are interchangeable priority policies behind one inference loop
(the framing of Aksenov et al. and Elidan et al.), so they are addressable
by *string spec* through a :class:`repro.core.registry.Registry`:
``get_scheduler("rnbp", low_p=0.4)``. This keeps
``repro.core.engine.BPConfig`` serializable end-to-end -- a config that
crossed a process boundary as JSON reconstructs the same scheduler.
``list_schedulers()`` is the sorted name listing (CLI ``choices=`` feed).

Serial RBP (the paper's SRBP baseline, Boost Fibonacci-heap) lives in
``repro.core.serial`` as a host-side numpy implementation; it is not a
``Scheduler`` (it owns its own loop) and is reached via
``BPConfig(scheduler="srbp")`` instead of this registry.
"""

from __future__ import annotations

from typing import Callable, List, Type

from repro.core.registry import Registry
from repro.core.schedulers.base import Scheduler
from repro.core.schedulers.lbp import LBP
from repro.core.schedulers.rbp import RBP
from repro.core.schedulers.rlx import RLX
from repro.core.schedulers.rlxtree import RLXTree
from repro.core.schedulers.rnbp import RnBP
from repro.core.schedulers.rs import RS

#: name -> Scheduler class. Names are the canonical serialized form.
#: A ``Registry`` (dict subclass): plain-dict reads keep working.
SCHEDULERS: Registry[Type] = Registry("scheduler", {
    "lbp": LBP,
    "rbp": RBP,
    "rs": RS,
    "rnbp": RnBP,
    "rlx": RLX,
    "rlxtree": RLXTree,
})


def register_scheduler(name: str, *,
                       overwrite: bool = False) -> Callable[[Type], Type]:
    """Class decorator registering a scheduler under ``name`` (lowercased).

    The class must satisfy the ``Scheduler`` protocol and be constructible
    from keyword arguments (so string specs stay serializable). Duplicate
    names raise ``ValueError`` unless ``overwrite=True``."""
    return SCHEDULERS.register(name, overwrite=overwrite)


def list_schedulers() -> List[str]:
    """Sorted registered scheduler names (the valid ``BPConfig.scheduler``
    string specs, minus the special-cased host-serial ``"srbp"``)."""
    return SCHEDULERS.names()


def get_scheduler(spec, **kwargs) -> Scheduler:
    """Resolve a scheduler spec: a registry name (+ constructor kwargs) or an
    already-built ``Scheduler`` instance (kwargs must then be empty)."""
    if isinstance(spec, str):
        if spec.lower() == "srbp":
            raise ValueError(
                "'srbp' is the host-serial baseline, not a frontier "
                "scheduler; use BPEngine(BPConfig(scheduler='srbp')).run()")
        return SCHEDULERS.lookup(spec)(**kwargs)
    if kwargs:
        raise ValueError("scheduler kwargs only apply to string specs, got "
                         f"instance {type(spec).__name__} plus {kwargs}")
    return spec


def scheduler_spec(sched: Scheduler):
    """Inverse of ``get_scheduler`` for registered types:
    ``(name, kwargs_dict)``. Raises KeyError for unregistered classes."""
    import dataclasses
    for name, cls in SCHEDULERS.items():
        if type(sched) is cls:
            return name, dataclasses.asdict(sched)
    raise KeyError(f"{type(sched).__name__} is not a registered scheduler")


__all__ = ["Scheduler", "LBP", "RBP", "RS", "RnBP", "RLX", "RLXTree",
           "SCHEDULERS", "get_scheduler", "register_scheduler",
           "list_schedulers", "scheduler_spec"]
