"""Message schedulings studied in the paper (Table IV).

| Algorithm  | Frontier selection            | Module   | Spec     |
|------------|-------------------------------|----------|----------|
| LBP        | all messages                  | lbp.py   | "lbp"    |
| RBP        | sort-and-select top-k (edges) | rbp.py   | "rbp"    |
| RS         | top-k vertices + depth-h splash | rs.py  | "rs"     |
| RnBP       | eps-filter + randomized p     | rnbp.py  | "rnbp"   | (paper's contribution)

Schedulers are interchangeable priority policies behind one inference loop
(the framing of Aksenov et al. and Elidan et al.), so they are addressable
by *string spec* through a registry: ``get_scheduler("rnbp", low_p=0.4)``.
This keeps ``repro.core.engine.BPConfig`` serializable end-to-end -- a
config that crossed a process boundary as JSON reconstructs the same
scheduler.

Serial RBP (the paper's SRBP baseline, Boost Fibonacci-heap) lives in
``repro.core.serial`` as a host-side numpy implementation; it is not a
``Scheduler`` (it owns its own loop) and is reached via
``BPConfig(scheduler="srbp")`` instead of this registry.
"""

from __future__ import annotations

from typing import Callable, Dict, Type

from repro.core.schedulers.base import Scheduler
from repro.core.schedulers.lbp import LBP
from repro.core.schedulers.rbp import RBP
from repro.core.schedulers.rs import RS
from repro.core.schedulers.rnbp import RnBP

#: name -> Scheduler class. Names are the canonical serialized form.
SCHEDULERS: Dict[str, Type] = {
    "lbp": LBP,
    "rbp": RBP,
    "rs": RS,
    "rnbp": RnBP,
}


def register_scheduler(name: str) -> Callable[[Type], Type]:
    """Class decorator registering a scheduler under ``name`` (lowercased).

    The class must satisfy the ``Scheduler`` protocol and be constructible
    from keyword arguments (so string specs stay serializable)."""
    key = name.lower()

    def deco(cls: Type) -> Type:
        SCHEDULERS[key] = cls
        return cls

    return deco


def get_scheduler(spec, **kwargs) -> Scheduler:
    """Resolve a scheduler spec: a registry name (+ constructor kwargs) or an
    already-built ``Scheduler`` instance (kwargs must then be empty)."""
    if isinstance(spec, str):
        key = spec.lower()
        if key == "srbp":
            raise ValueError(
                "'srbp' is the host-serial baseline, not a frontier "
                "scheduler; use BPEngine(BPConfig(scheduler='srbp')).run()")
        if key not in SCHEDULERS:
            raise KeyError(f"unknown scheduler {spec!r}; registered: "
                           f"{sorted(SCHEDULERS)}")
        return SCHEDULERS[key](**kwargs)
    if kwargs:
        raise ValueError("scheduler kwargs only apply to string specs, got "
                         f"instance {type(spec).__name__} plus {kwargs}")
    return spec


def scheduler_spec(sched: Scheduler):
    """Inverse of ``get_scheduler`` for registered types:
    ``(name, kwargs_dict)``. Raises KeyError for unregistered classes."""
    import dataclasses
    for name, cls in SCHEDULERS.items():
        if type(sched) is cls:
            return name, dataclasses.asdict(sched)
    raise KeyError(f"{type(sched).__name__} is not a registered scheduler")


__all__ = ["Scheduler", "LBP", "RBP", "RS", "RnBP", "SCHEDULERS",
           "get_scheduler", "register_scheduler", "scheduler_spec"]
