"""Structure-aware relaxed residual BP (Knoll et al. / arxiv 1206.5291).

``rlx`` cuts the edge axis into queues by *storage order*, which for
builder-made graphs interleaves the two directions of each undirected edge
(the even-pair layout) but carries no structural meaning. The improved
dynamic schedules line (arxiv 1206.5291) shows residual scheduling does
better when the unit of prioritization respects graph structure: updating
a message is only useful together with its tree/factor neighborhood, so
queues should hold structurally adjacent messages.

``rlxtree`` = the relaxed multi-queue selection of :mod:`rlx` applied in
**destination-vertex order**: scheduler state carries a permutation that
stably sorts real edges by ``edge_dst`` (padding last), computed once in
``init``. Contiguous queues of the permuted residuals then correspond to
contiguous runs of destination vertices -- each queue is a neighborhood
("subtree" of the grid/tree), so a queue's local top-k pops a message
*and* its structural competitors together, biasing rounds toward
depth-first propagation along subtrees rather than breadth-first over the
whole graph. The permutation is a traced argsort (batch-safe: computed
per-graph under the vmapped fold) carried as the scheduler state.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.graph import PGM
from repro.core.schedulers.rlx import queue_count, relaxed_frontier


@dataclasses.dataclass(frozen=True)
class RLXTree:
    """Relaxed multi-queue residual BP with structure-aware queues: edges
    are queued in destination-vertex order, so each queue covers a
    contiguous vertex neighborhood (tree/factor locality, arxiv 1206.5291).

    Same selection core and knobs as ``rlx`` (``queues``, ``sample``,
    ``p``); differs only in queue membership. ``init`` computes a stable
    argsort of ``edge_dst`` (masked edges sort last) carried as the
    scheduler state; ``select`` gathers residuals through it, runs the
    per-queue top-k of a sampled queue subset, and scatters the frontier
    back to storage order. Registry spec ``"rlxtree"``.
    """

    queues: int = 8          # Q: relaxation degree (queues to cut edges into)
    sample: float = 0.5      # fraction of queues admitted per round
    p: float = 1.0 / 256.0   # frontier multiplier: k_per_queue = p * |E| / Q
    inner_sweeps: int = 1

    def __post_init__(self):
        if self.queues < 1:
            raise ValueError(f"queues must be >= 1, got {self.queues}")
        if not 0.0 < self.sample <= 1.0:
            raise ValueError(f"sample must be in (0, 1], got {self.sample}")
        if not self.p > 0.0:
            raise ValueError(f"p must be > 0, got {self.p}")

    def init(self, pgm: PGM):
        # Stable sort keeps storage (even-pair) order within a destination,
        # and pushes padded edges past every real one so they land in the
        # trailing queues (where their zero residuals never pass a top-k).
        key = jnp.where(pgm.edge_mask, pgm.edge_dst,
                        jnp.int32(pgm.n_vertices))
        return jnp.argsort(key, stable=True).astype(jnp.int32)

    def select(self, pgm: PGM, residuals: jax.Array, eps: float,
               rng: jax.Array, state, unconverged: jax.Array):
        order = state
        e = residuals.shape[0]
        q = queue_count(e, self.queues)
        k = jnp.clip(jnp.round(self.p * pgm.traced_edge_count()
                               .astype(jnp.float32) / q).astype(jnp.int32),
                     1, e // q)
        res = jnp.where(pgm.edge_mask, residuals, 0.0)[order]
        frontier_perm = relaxed_frontier(
            res.reshape(q, e // q), k, self.sample, rng).reshape(e)
        frontier = jnp.zeros((e,), bool).at[order].set(frontier_perm)
        return frontier & pgm.edge_mask, order
