"""Randomized BP -- the paper's contribution (SS IV).

Frontier = two filters over all directed edges:
  1. *eps filter*: drop messages whose next update moves them < eps
     (they are already locally converged; after Yang et al.),
  2. *random filter*: keep a Bernoulli(p) subset of the survivors
     (cuRAND per-thread on the GPU; threefry here -- pure elementwise,
     no sort, which is the entire point).

Dynamic p (SS IV-A): track EdgeRatio = NewEdgeCount / OldEdgeCount of
unconverged edges between consecutive rounds. EdgeRatio > 0.9 means the run
is stalling -> use LowP (sequentialism, convergence mode); otherwise HighP
(parallelism, speed mode). The paper locks HighP = 1.0 for the synthetic
benchmarks and sweeps LowP in {0.7, 0.4, 0.1}; protein runs use (0.9, 0.4).

Carried state: previous round's unconverged-edge count.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.graph import PGM


@dataclasses.dataclass(frozen=True)
class RnBP:
    """Randomized BP (the paper's contribution): eps-filter + Bernoulli(p)
    keep, with a two-mode dynamic p.

    ``select`` keeps each unconverged real edge (residual >= eps) with
    probability ``p`` -- pure elementwise work, no sort. The carried state
    is the previous round's unconverged count (() f32): when the ratio
    new/old exceeds ``ratio_threshold`` the run is stalling and ``low_p``
    (convergence mode) is used, otherwise ``high_p`` (speed mode).
    Stochastic: consumes one (E,)-shaped uniform draw per round from the
    engine's RNG stream. Registry spec ``"rnbp"``.
    """

    low_p: float = 0.7
    high_p: float = 1.0
    ratio_threshold: float = 0.9
    inner_sweeps: int = 1

    def init(self, pgm: PGM):
        # OldEdgeCount starts at "everything unconverged". Traced count so a
        # vmapped bucket carries each graph's own controller state.
        return pgm.traced_edge_count().astype(jnp.float32)

    def select(self, pgm: PGM, residuals: jax.Array, eps: float,
               rng: jax.Array, state, unconverged: jax.Array):
        old_count = state
        new_count = unconverged.astype(jnp.float32)
        edge_ratio = new_count / jnp.maximum(old_count, 1.0)
        p = jnp.where(edge_ratio > self.ratio_threshold,
                      self.low_p, self.high_p)
        # Filter 1: eps-prune.
        candidates = (residuals >= eps) & pgm.edge_mask
        # Filter 2: randomized keep. One uniform per edge -- O(E) elementwise,
        # the low-overhead replacement for sort-and-select.
        keep = jax.random.uniform(rng, residuals.shape) < p
        frontier = candidates & keep
        return frontier, new_count
