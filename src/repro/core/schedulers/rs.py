"""Residual Splash, bulk-parallel variant (paper SS III-A; Gonzalez et al. 09).

Vertex residual = max residual over incoming messages. The top-k vertices are
selected greedily; a *splash* -- the depth-h BFS ball around each root -- is
then updated. The original RS walks the BFS tree sequentially; the paper's
GPU version updates splashes in bulk. We realize the splash as (a) an h-hop
mask expansion over the (static) edge list to find the ball, then (b) ``h``
masked update sweeps inside the ball (the runner's ``inner_sweeps``), which
reproduces the root-outward information flow of the sequential walk in
bulk-synchronous form. Paper locks h = 2.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.graph import PGM


@dataclasses.dataclass(frozen=True)
class RS:
    """Residual Splash: top-k residual *vertices*, each updated with a
    depth-``h`` splash (the BFS ball around the root).

    ``select`` returns the ``(E,) bool`` mask of all edges inside the
    h-hop balls of the ``k = max(1, p * V)`` highest-residual vertices;
    the runner then applies ``inner_sweeps == h`` masked update passes
    inside that frontier, reproducing the sequential root-outward walk in
    bulk-synchronous form. Deterministic; no carried state. Registry spec
    ``"rs"``.
    """

    p: float = 1.0 / 128.0
    h: int = 2
    inner_sweeps: int = 2  # keep == h

    def init(self, pgm: PGM):
        return ()

    def select(self, pgm: PGM, residuals: jax.Array, eps: float,
               rng: jax.Array, state, unconverged: jax.Array):
        # Vertex residuals: max over incoming edges (paper SS II-B).
        vres = jax.ops.segment_max(
            jnp.where(pgm.edge_mask, residuals, 0.0), pgm.edge_dst,
            num_segments=pgm.n_vertices)
        # dummy + padding vertices (mask, not a static slice: batch-safe)
        real = jnp.arange(vres.shape[0]) < pgm.traced_vertex_count()
        vres = jnp.where(real, vres, 0.0)
        # k roots. The paper parameterizes frontiers in messages (p * 2|E|);
        # a depth-h splash touches ~deg^h edges, so k roots ~ p*2|E| / deg^h
        # messages. We select k = max(1, p * V) roots, the standard RS
        # choice; under batching k_max is the bucket ceiling and the traced
        # per-graph k indexes into the sorted top-k.
        k_max = max(1, int(round(self.p * pgm.n_real_vertices)))
        k_max = min(k_max, vres.shape[0])
        k = jnp.clip(jnp.round(self.p * pgm.traced_vertex_count()
                               .astype(jnp.float32)).astype(jnp.int32),
                     1, k_max)
        thresh = jax.lax.top_k(vres, k_max)[0][k - 1]
        in_ball = (vres >= jnp.maximum(thresh, 1e-30))
        # Expand the ball h hops: a vertex joins if any neighbour is in.
        for _ in range(self.h):
            hop = jax.ops.segment_max(
                in_ball[pgm.edge_src].astype(jnp.int32) *
                pgm.edge_mask.astype(jnp.int32),
                pgm.edge_dst, num_segments=pgm.n_vertices)
            in_ball = in_ball | (hop > 0)
        # Frontier: every directed edge inside the ball.
        frontier = (in_ball[pgm.edge_src] & in_ball[pgm.edge_dst]
                    & pgm.edge_mask)
        return frontier, state
