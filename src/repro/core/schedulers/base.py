"""Scheduler protocol for frontier-based BP (paper Algorithm 1).

A scheduler owns ``GenerateFrontier``: given the fresh residuals of *all*
directed edges it returns a boolean frontier mask plus its own carried state.
Schedulers are static Python objects (hashable config); their ``init``/
``select`` are traced into the single ``lax.while_loop`` of the runner, so
all shapes are fixed and selection is pure.

``select`` receives ``unconverged`` (count of edges with residual >= eps this
round) because RnBP's dynamic-p controller consumes it; other schedulers
ignore it.

Batch-safety contract (``repro.core.batch`` vmaps ``init``/``select`` over a
bucket of same-shape graphs): implementations must not branch on *per-graph*
real sizes statically. Static shapes / ``pgm.n_real_*`` ints are bucket-wide
ceilings; anything per-graph (frontier size k, padding masks, controller
state) must come from the traced ``pgm.traced_edge_count()`` /
``pgm.traced_vertex_count()`` scalars so one trace serves every graph in the
bucket.
"""

from __future__ import annotations

from typing import Any, Protocol, Tuple

import jax

from repro.core.graph import PGM


class Scheduler(Protocol):
    #: number of masked update sweeps the runner applies per selected frontier
    #: (1 for everything except Residual Splash's depth-h inner propagation).
    inner_sweeps: int

    def init(self, pgm: PGM) -> Any:
        """Initial carried state (a pytree of arrays; may be ())."""
        ...

    def select(self, pgm: PGM, residuals: jax.Array, eps: float,
               rng: jax.Array, state: Any,
               unconverged: jax.Array) -> Tuple[jax.Array, Any]:
        """Return ``(frontier_mask(E,), new_state)``."""
        ...
