"""Unified, resumable BP engine: config-driven entry, chunked stepping,
converged-graph evacuation.

The paper's central knob is the *scheduling policy* (LBP/RBP/RS/RnBP); the
engine makes it -- and everything else -- one frozen, serializable
``BPConfig`` behind one inference loop:

    engine = BPEngine(BPConfig(scheduler="rnbp",
                               scheduler_kwargs={"low_p": 0.4},
                               eps=1e-3, max_rounds=2000))
    res = engine.run(pgm, jax.random.key(0))            # one-shot
    res_list = engine.run_many(pgms, jax.random.key(0)) # bucketed stream

Chunked resume is first-class instead of a private ``_init_logm`` backdoor:

    state = engine.init(pgm, rng)           # BPState: a checkpointable pytree
    while not engine.finished(state):
        state = engine.step(state)          # one jitted chunk of <= chunk_rounds
    res = engine.result(state)

``step`` carries the *entire* trajectory (messages, scheduler state, the RNG
stream, round/update counters, history), so N rounds via repeated ``step``
are bit-identical to N rounds in one ``run`` -- the property the resilience
layer (repro.ft) and the serving driver both build on.

On the batched path ``step`` returns per-graph convergence, which the
serving layer exploits: between chunks, converged graphs are *evacuated*
(their results released immediately) and their batch slots *backfilled* from
the pending queue, so straggler rounds stop costing the whole bucket. Sweep
accounting (device vs useful) quantifies the win against the
run-every-bucket-to-completion baseline. The serving *pipeline* -- online
request iterators, double-buffered slot dispatch, prefetch staging, bucket
compaction -- lives in ``repro.core.serving``; ``serve(stream)`` here is its
synchronous compatibility wrapper.

``run_bp`` / ``run_bp_batch`` / ``run_bp_many`` / ``run_srbp`` remain as
deprecated wrappers with exact-trajectory parity (they delegate here).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, List, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import messages as M
from repro.core.batch import BatchedPGM, batch_keys, bucket_pgms
from repro.core.graph import PGM
from repro.core.schedulers import get_scheduler
from repro.core.schedulers.base import Scheduler

__all__ = ["BPConfig", "BPEngine", "BPResult", "BPState", "ServeResult",
           "ServeStats"]


# --------------------------------------------------------------- results --

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BPResult:
    """Finished-trajectory record returned by ``BPEngine.run``/``result``.

    Shapes below are the single-graph case; on the batched path every field
    carries a leading ``(B,)`` axis. ``converged`` is True iff every real
    edge's residual fell below the config's ``eps`` within ``max_rounds``
    sweeps; ``beliefs`` are valid either way (the best marginals at exit).
    """

    beliefs: jax.Array          # (V, S) log-marginals ((B, V, S) batched)
    logm: jax.Array             # (E, S) final messages
    rounds: jax.Array           # () int32: bulk sweeps executed
    updates: jax.Array          # () uint32: committed messages (exact count;
                                #   cast at the boundary -- f32 accumulation
                                #   lost precision past ~16M messages)
    converged: jax.Array        # () bool
    max_residual: jax.Array     # () f32 at exit
    unconverged_history: jax.Array  # (max_rounds,) int32, -1 past exit
    sched_state: Any            # scheduler carry (chunked-resume leftover)


# ---------------------------------------------------------------- config --

def _freeze_kwargs(kw) -> Tuple[Tuple[str, Any], ...]:
    if isinstance(kw, Mapping):
        return tuple(sorted(kw.items()))
    return tuple(kw)


@dataclasses.dataclass(frozen=True)
class BPConfig:
    """Frozen, hashable inference config; the engine's single entry knob.

    ``scheduler`` is a registry spec string ("lbp"/"rbp"/"rs"/"rnbp" --
    serializable end-to-end via ``to_dict``/``from_dict``) or a prebuilt
    ``Scheduler`` instance; ``scheduler_kwargs`` feed the registry
    constructor. ``"srbp"`` selects the host-serial baseline (``run`` only).

    ``backend`` picks the message-update implementation by name ("ref" |
    "pallas", resolved through ``repro.kernels.ops.UPDATE_BACKENDS``) or is a
    ``(pgm, logm) -> (cand, resid)`` callable. ``batch_backend`` optionally
    overrides the batched path with a natively batched update (callable or
    "pallas"); the default folds the bucket into a disjoint union and reuses
    the single-graph ``backend``.

    ``chunk_rounds`` bounds rounds per ``step`` (None = run to
    ``max_rounds`` in one chunk); ``history`` sizes the per-round
    unconverged-count buffer (paper Figs 2/4).

    ``admission`` is the *serving-side* policy knob: a registry spec string
    ("fifo" | "windowed" | "residual", resolved through
    ``repro.core.serving.ADMISSION_POLICIES``; ``admission_kwargs`` feed
    the constructor) or a prebuilt ``AdmissionPolicy``. It only matters to
    ``serve``/``serve_async``/``ServingPipeline`` -- one-shot ``run`` paths
    ignore it -- and rides the config so a serialized deployment spec pins
    its admission behavior alongside its scheduler.
    """

    scheduler: Any = "lbp"
    scheduler_kwargs: Any = ()
    eps: float = 1e-3
    max_rounds: int = 2000
    damping: float = 0.0
    backend: Any = "ref"
    batch_backend: Any = None
    chunk_rounds: int | None = None
    history: bool = True
    admission: Any = "fifo"
    admission_kwargs: Any = ()

    def __post_init__(self):
        object.__setattr__(self, "scheduler_kwargs",
                           _freeze_kwargs(self.scheduler_kwargs))
        object.__setattr__(self, "admission_kwargs",
                           _freeze_kwargs(self.admission_kwargs))
        if not self.eps > 0:
            raise ValueError(f"eps must be > 0, got {self.eps}")
        if self.max_rounds < 1:
            raise ValueError(f"max_rounds must be >= 1, got {self.max_rounds}")
        if not 0.0 <= self.damping < 1.0:
            raise ValueError(f"damping must be in [0, 1), got {self.damping}")
        if self.chunk_rounds is not None and self.chunk_rounds < 1:
            raise ValueError("chunk_rounds must be >= 1 or None, got "
                             f"{self.chunk_rounds}")

    def make_scheduler(self) -> Scheduler:
        return get_scheduler(self.scheduler, **dict(self.scheduler_kwargs))

    def to_dict(self) -> dict:
        """JSON-ready form. Requires a string (or registered) scheduler spec
        and string backends -- the serializable subset."""
        from repro.core.schedulers import scheduler_spec
        d = dataclasses.asdict(self)
        if not isinstance(self.scheduler, str):
            name, kw = scheduler_spec(self.scheduler)
            d["scheduler"], d["scheduler_kwargs"] = name, _freeze_kwargs(kw)
        for f in ("backend", "batch_backend"):
            if d[f] is not None and not isinstance(d[f], str):
                raise ValueError(f"{f} is a callable; not serializable")
        if not isinstance(d["admission"], str):
            raise ValueError("admission is a policy instance; use a registry "
                             "spec string for a serializable config")
        d["scheduler_kwargs"] = dict(d["scheduler_kwargs"])
        d["admission_kwargs"] = dict(d["admission_kwargs"])
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "BPConfig":
        return cls(**dict(d))


# ----------------------------------------------------------------- state --

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BPState:
    """Resumable trajectory state -- everything a chunk boundary must carry.

    Single-graph states hold scalar counters; batched states carry a leading
    (B,) axis on every counter plus per-graph RNG keys. ``chunk_iters`` is
    bookkeeping (loop iterations executed by the last ``step``), not part of
    the trajectory.
    """

    graph: Any                  # PGM | BatchedPGM
    logm: jax.Array             # (E, S) / (B, E, S) current messages
    sched_state: Any            # scheduler carry
    rng: jax.Array              # carried key / (B,) keys
    rounds: jax.Array           # () / (B,) int32 cumulative rounds
    done: jax.Array             # () / (B,) bool per-graph convergence
    updates: jax.Array          # () / (B,) uint32 committed messages
    unconverged_history: jax.Array  # (H,) / (B, H) int32
    max_residual: jax.Array     # () / (B,) f32
    chunk_iters: jax.Array      # () int32, diagnostics only

    @property
    def batched(self) -> bool:
        return isinstance(self.graph, BatchedPGM)

    @property
    def size(self) -> int:
        return self.graph.size if self.batched else 1


# ------------------------------------------------------- chunked kernels --

def _carry_of(state: BPState):
    return (state.logm, state.sched_state, state.rng, state.rounds,
            state.done, state.updates, state.unconverged_history,
            state.max_residual, jnp.int32(0))


def _state_with(state: BPState, carry) -> BPState:
    logm, sstate, rng, rounds, done, updates, hist, max_r, iters = carry
    return dataclasses.replace(
        state, logm=logm, sched_state=sstate, rng=rng, rounds=rounds,
        done=done, updates=updates, unconverged_history=hist,
        max_residual=max_r, chunk_iters=iters)


@partial(jax.jit, static_argnames=("scheduler", "damping", "update_fn",
                                   "track_history"))
def _chunk_single(pgm: PGM, carry, limit, eps, *, scheduler: Scheduler,
                  damping: float, update_fn: Callable, track_history: bool):
    """Run the frontier loop (paper Algorithm 1) until convergence or
    ``rounds >= limit``. Body identical to the historic ``run_bp`` loop, so
    chunked execution reproduces monolithic trajectories bit-for-bit."""

    def cond(c):
        _, _, _, rounds, done, _, _, _, _ = c
        return (~done) & (rounds < limit)

    def body(c):
        logm, sstate, rng, rounds, done, updates, hist, _, iters = c
        rng, sel_key = jax.random.split(rng)
        cand, r = update_fn(pgm, logm)
        unconverged = jnp.sum((r >= eps) & pgm.edge_mask).astype(jnp.int32)
        frontier, sstate = scheduler.select(pgm, r, eps, sel_key, sstate,
                                            unconverged)
        # Converged -> commit nothing (IsConverged precedes Update in Alg. 1).
        newly_done = unconverged == 0
        frontier = frontier & ~newly_done
        logm = M.apply_frontier(logm, cand, frontier, damping)
        # Residual Splash: h-1 extra masked sweeps inside the same frontier.
        for _ in range(scheduler.inner_sweeps - 1):
            cand, _ = update_fn(pgm, logm)
            logm = M.apply_frontier(logm, cand, frontier, damping)
        updates = updates + jnp.sum(frontier).astype(jnp.uint32) \
            * jnp.uint32(scheduler.inner_sweeps)
        if track_history:
            hist = hist.at[rounds].set(unconverged)
        rounds = rounds + jnp.where(newly_done, 0,
                                    jnp.int32(scheduler.inner_sweeps))
        max_r = jnp.max(r)
        return (logm, sstate, rng, rounds, newly_done, updates, hist, max_r,
                iters + 1)

    return jax.lax.while_loop(cond, body, carry)


def _where_keys(mask: jax.Array, new: jax.Array, old: jax.Array) -> jax.Array:
    return jnp.where(mask, new, old)


def _bcast_where(mask: jax.Array, new: jax.Array, old: jax.Array) -> jax.Array:
    m = mask.reshape(mask.shape + (1,) * (jnp.ndim(new) - 1))
    return jnp.where(m, new, old)


@partial(jax.jit, static_argnames=("scheduler", "damping", "update_fn",
                                   "batch_update_fn", "track_history"))
def _chunk_batch(batch: BatchedPGM, carry, limit, eps, *,
                 scheduler: Scheduler, damping: float, update_fn: Callable,
                 batch_update_fn: Callable | None, track_history: bool):
    """Whole-bucket frontier loop until every graph converges or reaches its
    per-graph ``limit`` (B,). Each graph's body effects are gated on its own
    ``active`` flag, so graphs at different cumulative rounds (evacuation
    backfill) each reproduce their solo trajectory exactly: a frozen graph
    commits nothing, consumes no RNG, and advances no counters."""
    bpgm = batch.pgm
    b, e = batch.size, batch.n_edges
    s = batch.n_states_max
    if batch_update_fn is None:
        # Mesh-aware fold: a sharded backend (repro.dist) advertises its
        # mesh, and the (B*E) union grid is laid out across it.
        union = batch.folded(mesh=getattr(update_fn, "mesh", None),
                             axis=getattr(update_fn, "axis", "bp"))

        def batch_update_fn(_, logm):
            cand, r = update_fn(union, logm.reshape(b * e, s))
            return cand.reshape(b, e, s), r.reshape(b, e)

    select = jax.vmap(
        lambda p, r, k, st, u: scheduler.select(p, r, eps, k, st, u))
    commit = jax.vmap(partial(M.apply_frontier, damping=damping))

    def cond(c):
        _, _, _, rounds, done, _, _, _, _ = c
        return jnp.any((~done) & (rounds < limit))

    def body(c):
        logm, sstate, keys, rounds, done, updates, hist, _, iters = c
        active = (~done) & (rounds < limit)                     # (B,)
        split = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
        keys = _where_keys(active, split[:, 0], keys)
        sel_keys = split[:, 1]
        cand, r = batch_update_fn(bpgm, logm)
        unconverged = jnp.sum((r >= eps) & bpgm.edge_mask,
                              axis=1).astype(jnp.int32)         # (B,)
        frontier, new_sstate = select(bpgm, r, sel_keys, sstate, unconverged)
        sstate = jax.tree.map(partial(_bcast_where, active),
                              new_sstate, sstate)
        newly_done = (unconverged == 0) & active
        frontier = frontier & active[:, None] & ~newly_done[:, None]
        logm = commit(logm, cand, frontier)
        for _ in range(scheduler.inner_sweeps - 1):
            cand, _ = batch_update_fn(bpgm, logm)
            logm = commit(logm, cand, frontier)
        updates = updates + jnp.sum(frontier, axis=1).astype(jnp.uint32) \
            * jnp.uint32(scheduler.inner_sweeps)
        if track_history:
            hist = jax.vmap(lambda h, i, u, a: jnp.where(
                a, h.at[i].set(u), h))(hist, rounds, unconverged, active)
        rounds = rounds + jnp.where(newly_done | ~active, 0,
                                    jnp.int32(scheduler.inner_sweeps))
        max_r = jnp.max(r, axis=1)
        return (logm, sstate, keys, rounds, done | newly_done, updates, hist,
                max_r, iters + 1)

    return jax.lax.while_loop(cond, body, carry)


@partial(jax.jit, static_argnames=("scheduler", "track_history", "hist_len"))
def _init_single(pgm: PGM, rng, *, scheduler: Scheduler, track_history: bool,
                 hist_len: int):
    return (M.init_messages(pgm), scheduler.init(pgm), rng, jnp.int32(0),
            jnp.asarray(False), jnp.uint32(0),
            jnp.full((hist_len if track_history else 1,), -1, jnp.int32),
            jnp.float32(jnp.inf))


@partial(jax.jit, static_argnames=("scheduler", "track_history", "hist_len"))
def _init_batch(batch: BatchedPGM, keys, *, scheduler: Scheduler,
                track_history: bool, hist_len: int):
    b = batch.size
    return (jax.vmap(M.init_messages)(batch.pgm),
            jax.vmap(scheduler.init)(batch.pgm), keys,
            jnp.zeros((b,), jnp.int32), jnp.zeros((b,), bool),
            jnp.zeros((b,), jnp.uint32),
            jnp.full((b, hist_len if track_history else 1), -1, jnp.int32),
            jnp.full((b,), jnp.inf, jnp.float32))


@jax.jit
def _beliefs_single(pgm: PGM, logm):
    return M.beliefs(pgm, logm)


@jax.jit
def _beliefs_batch(bpgm: PGM, logm):
    return jax.vmap(M.beliefs)(bpgm, logm)


@partial(jax.jit, static_argnames=("scheduler",))
def _load_slot(state: BPState, j, elem: PGM, key, *, scheduler: Scheduler):
    """Replace batch slot ``j`` with a fresh graph: swap the graph leaves and
    reset the slot's trajectory (messages, scheduler state, counters, RNG)
    exactly as ``init`` would for a solo run."""
    batch = state.graph
    new_pgm = jax.tree.map(lambda full, one: full.at[j].set(one),
                           batch.pgm, elem)
    sstate = jax.tree.map(lambda full, one: full.at[j].set(one),
                          state.sched_state, scheduler.init(elem))
    return dataclasses.replace(
        state,
        graph=dataclasses.replace(batch, pgm=new_pgm),
        logm=state.logm.at[j].set(M.init_messages(elem)),
        sched_state=sstate,
        rng=state.rng.at[j].set(key),
        rounds=state.rounds.at[j].set(0),
        done=state.done.at[j].set(False),
        updates=state.updates.at[j].set(0),
        unconverged_history=state.unconverged_history.at[j].set(-1),
        max_residual=state.max_residual.at[j].set(jnp.inf))


# ------------------------------------------------------- serving driver --

@dataclasses.dataclass
class ServeStats:
    """Sweep accounting for ``BPEngine.serve``.

    Sweeps are counted in *masked update passes per graph slot* (one loop
    iteration of a B-wide bucket = B device sweeps x ``inner_sweeps``);
    ``useful_sweeps`` counts only rounds advanced on live graphs, so
    ``wasted_sweeps`` is exactly the straggler/padding overhead evacuation
    is meant to shrink."""

    chunks: int = 0
    device_sweeps: int = 0
    useful_sweeps: int = 0
    evacuated: int = 0
    backfilled: int = 0
    #: (chunk index at evacuation, input graph index) per evacuated graph
    evacuation_log: List[Tuple[int, int]] = dataclasses.field(
        default_factory=list)

    @property
    def wasted_sweeps(self) -> int:
        return self.device_sweeps - self.useful_sweeps


@dataclasses.dataclass
class ServeResult:
    """``BPEngine.serve`` output: one ``BPResult`` per request (input
    order, each sliced to single-graph shapes) plus the run's sweep
    accounting (``ServeStats``)."""

    results: List[BPResult]     # per-request, input order
    stats: ServeStats


# ---------------------------------------------------------------- engine --

class BPEngine:
    """The unified BP inference engine (see module docstring).

    One engine instance = one resolved (scheduler, backend) pair; reuse it
    across calls so jit caches stay warm. All methods accept either a single
    ``PGM`` or a ``BatchedPGM`` bucket; ``run_many``/``serve`` take
    heterogeneous graph lists.
    """

    def __init__(self, config: BPConfig | None = None, **overrides):
        config = config or BPConfig()
        if overrides:
            config = dataclasses.replace(config, **overrides)
        self.config = config
        self.is_serial = (isinstance(config.scheduler, str)
                          and config.scheduler.lower() == "srbp")
        self.scheduler: Scheduler | None = (
            None if self.is_serial else config.make_scheduler())
        self.update_fn = self._resolve_backend(config.backend)
        self.batch_update_fn = (
            None if config.batch_backend is None
            else self._resolve_backend(config.batch_backend, batched=True))

    @staticmethod
    def _resolve_backend(backend, *, batched: bool = False) -> Callable:
        if callable(backend):
            return backend
        if backend == "ref" and not batched:
            return M.ref_update
        from repro.kernels.ops import get_update_fn
        return get_update_fn(backend, batched=batched)

    # -- lifecycle ---------------------------------------------------------

    def init(self, graph: PGM | BatchedPGM, rng: jax.Array) -> BPState:
        """Fresh trajectory state for ``graph``. ``rng`` is one key (split
        per-graph for buckets) or a (B,) key array."""
        if self.is_serial:
            raise NotImplementedError(
                "scheduler='srbp' is host-serial: use run(), not init/step")
        cfg, sched = self.config, self.scheduler
        if isinstance(graph, BatchedPGM):
            carry = _init_batch(graph, batch_keys(rng, graph),
                                scheduler=sched, track_history=cfg.history,
                                hist_len=cfg.max_rounds)
        else:
            carry = _init_single(graph, rng, scheduler=sched,
                                 track_history=cfg.history,
                                 hist_len=cfg.max_rounds)
        return BPState(graph, *carry, chunk_iters=jnp.int32(0))

    def step(self, state: BPState, *,
             chunk_rounds: int | None = None) -> BPState:
        """Advance one jitted chunk: at most ``chunk_rounds`` further rounds
        (per graph), stopping early on convergence. A finished state is a
        no-op. Bit-identical to running the same total rounds in one chunk.
        """
        cfg = self.config
        chunk = chunk_rounds or cfg.chunk_rounds or cfg.max_rounds
        limit = jnp.minimum(state.rounds + chunk, cfg.max_rounds)
        kw = dict(scheduler=self.scheduler, damping=cfg.damping,
                  update_fn=self.update_fn, track_history=cfg.history)
        if state.batched:
            carry = _chunk_batch(state.graph, _carry_of(state), limit,
                                 cfg.eps, batch_update_fn=self.batch_update_fn,
                                 **kw)
        else:
            carry = _chunk_single(state.graph, _carry_of(state), limit,
                                  cfg.eps, **kw)
        return _state_with(state, carry)

    def finished(self, state: BPState) -> bool:
        """True when every graph converged or exhausted ``max_rounds``."""
        return bool(jnp.all(state.done |
                            (state.rounds >= self.config.max_rounds)))

    def result(self, state: BPState) -> BPResult:
        """Finalize a state into a ``BPResult`` (computes beliefs)."""
        if state.batched:
            beliefs = _beliefs_batch(state.graph.pgm, state.logm)
        else:
            beliefs = _beliefs_single(state.graph, state.logm)
        return BPResult(beliefs=beliefs, logm=state.logm, rounds=state.rounds,
                        updates=state.updates, converged=state.done,
                        max_residual=state.max_residual,
                        unconverged_history=state.unconverged_history,
                        sched_state=state.sched_state)

    # -- one-shot ----------------------------------------------------------

    def run(self, graph: PGM | BatchedPGM, rng: jax.Array | None = None, *,
            state: BPState | None = None) -> BPResult:
        """One-shot inference. With ``chunk_rounds`` set, runs chunk by chunk
        (same trajectory, checkpointable); otherwise one ``while_loop``.
        ``state`` resumes an existing trajectory instead of starting fresh.
        For ``scheduler='srbp'`` runs the host-serial baseline and returns an
        ``SRBPResult``."""
        if self.is_serial:
            from repro.core.serial import srbp_run
            kw = dict(self.config.scheduler_kwargs)
            return srbp_run(graph, eps=self.config.eps, **kw)
        if state is None:
            if rng is None:
                raise ValueError("run() needs an rng key (or a state)")
            state = self.init(graph, rng)
        while not self.finished(state):
            state = self.step(state)
        return self.result(state)

    def run_many(self, pgms: Sequence[PGM], rng: jax.Array, *,
                 growth: float = 2.0,
                 max_batch: int | None = None) -> List[BPResult]:
        """Bucket ``pgms`` (shape-homogeneous padded batches), run each
        bucket, return per-graph results in input order. Per-graph keys are
        ``fold_in(rng, input position)`` so the RNG stream is independent of
        the bucketing policy. (Stochastic schedulers draw per-edge
        randomness over the *padded* edge axis, so a bucketing change that
        re-pads a graph can still alter RnBP/RBP trajectories -- the fixed
        point reached, not the answer quality.)"""
        results: List[BPResult | None] = [None] * len(pgms)
        for bucket in bucket_pgms(pgms, growth=growth, max_batch=max_batch):
            keys = jnp.stack([jax.random.fold_in(rng, i)
                              for i in bucket.indices])
            res = self.run(bucket.batch, keys)
            for j, gi in enumerate(bucket.indices):
                results[gi] = jax.tree.map(lambda x: x[j], res)
        return results  # type: ignore[return-value]

    # -- serving with evacuation ------------------------------------------

    def _slice_result(self, state: BPState, j: int) -> BPResult:
        elem = state.graph.graph(j)
        sub = jax.tree.map(lambda x: x[j], (
            state.logm, state.rounds, state.done, state.updates,
            state.unconverged_history, state.max_residual, state.sched_state))
        logm, rounds, done, updates, hist, max_r, sstate = sub
        return BPResult(beliefs=_beliefs_single(elem, logm), logm=logm,
                        rounds=rounds, updates=updates, converged=done,
                        max_residual=max_r, unconverged_history=hist,
                        sched_state=sstate)

    def serve(self, stream: Sequence[PGM], rng: jax.Array, *,
              growth: float = 2.0, max_batch: int | None = None,
              chunk_rounds: int | None = None,
              evacuate: bool = True) -> ServeResult:
        """Serve a materialized request stream through rolling, evacuating
        buckets -- the synchronous compatibility wrapper over
        ``repro.core.serving`` (one resident bucket, no compaction, stream
        staged up front: the legacy cadence, chunk for chunk).

        Requests are grouped by bucket shape key and padded to their
        *group's* joint ceiling; each group runs as one resident batch of
        width ``min(max_batch, group size)``. After every chunk, converged
        (or round-exhausted) graphs are evacuated -- their results released
        immediately -- and their slots backfilled from the group's pending
        queue, so one straggler no longer holds a whole bucket's worth of
        finished work hostage. ``evacuate=False`` is the run-every-bucket-
        to-completion baseline (the PR-1 behavior) over the *same* padded
        groups, so its per-graph results and sweep accounting are exactly
        comparable.

        Per-graph RNG keys are ``fold_in(rng, input position)``, so results
        are independent of ``max_batch``/``evacuate`` and match ``run_many``
        whenever the padded shapes coincide (always true for same-shape
        groups). Caveat shared with ``run_many``: stochastic schedulers
        draw per-edge randomness over the *padded* edge axis, so policies
        that change a graph's padded shape (group ceiling here vs.
        per-sub-bucket max in ``run_many``) can legitimately alter
        RnBP/RBP trajectories -- the fixed point, not the answer quality.

        For online iterators, pipelined host/device overlap, bucket
        compaction, non-FIFO admission policies, and threaded ingestion,
        use ``repro.core.serving.serve_async`` (bitwise-equal per-request
        results on the same materialized stream). The config's
        ``admission`` policy applies here too (the default ``"fifo"``
        reproduces the historic cadence exactly).
        """
        from repro.core.serving import serve_async
        rep = serve_async(self, list(stream), rng, growth=growth,
                          max_batch=max_batch, chunk_rounds=chunk_rounds,
                          evacuate=evacuate, compact=False, slots=1,
                          prefetch=None)
        return ServeResult(rep.results, rep.stats)
