"""Exact inference oracles (host-side numpy): brute force + variable
elimination. Used for the paper's Fig-5 correctness test (KL-divergence of
BP marginals vs exact on Ising 10x10, C=2) and for unit tests.

Log-space throughout.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def _logsumexp(a: np.ndarray, axis=None) -> np.ndarray:
    m = np.max(a, axis=axis, keepdims=True)
    m = np.where(np.isfinite(m), m, 0.0)
    out = np.log(np.sum(np.exp(a - m), axis=axis)) + np.squeeze(m, axis=axis)
    return out


def brute_force_marginals(n_vertices: int, edges: np.ndarray,
                          unary: Sequence[np.ndarray],
                          pairwise: Sequence[np.ndarray]) -> List[np.ndarray]:
    """Enumerate the full joint. Only for tiny graphs (prod of states <~ 1e7)."""
    sizes = [len(u) for u in unary]
    total = int(np.prod(sizes))
    assert total <= 10_000_000, "graph too large for brute force"
    log_joint = np.zeros(sizes, dtype=np.float64)
    for v, u in enumerate(unary):
        shape = [1] * n_vertices
        shape[v] = sizes[v]
        log_joint = log_joint + np.log(np.asarray(u)).reshape(shape)
    for k, (i, j) in enumerate(np.asarray(edges)):
        i, j = int(i), int(j)
        table = np.log(np.asarray(pairwise[k], dtype=np.float64))
        reshaped = np.moveaxis(
            table.reshape([sizes[i], sizes[j]] + [1] * (n_vertices - 2)),
            [0, 1], [i, j])
        log_joint = log_joint + reshaped
    z = _logsumexp(log_joint.ravel(), axis=0)
    marginals = []
    for v in range(n_vertices):
        axes = tuple(a for a in range(n_vertices) if a != v)
        lm = _logsumexp(log_joint, axis=axes) - z
        marginals.append(np.exp(lm))
    return marginals


class _Factor:
    __slots__ = ("vars", "table")

    def __init__(self, vars_: Tuple[int, ...], table: np.ndarray):
        self.vars = tuple(vars_)
        self.table = table  # log-space, ndim == len(vars)

    def multiply(self, other: "_Factor") -> "_Factor":
        all_vars = tuple(sorted(set(self.vars) | set(other.vars)))
        def expand(f: "_Factor") -> np.ndarray:
            idx = [all_vars.index(v) for v in f.vars]
            t = f.table
            # move existing axes into sorted order, then insert size-1 axes
            order = np.argsort(idx)
            t = np.transpose(t, order)
            sorted_idx = [idx[o] for o in order]
            shape = [1] * len(all_vars)
            for pos, v in zip(sorted_idx, [f.vars[o] for o in order]):
                shape[pos] = f.table.shape[f.vars.index(v)]
            return t.reshape(shape)
        return _Factor(all_vars, expand(self) + expand(other))

    def eliminate(self, var: int) -> "_Factor":
        ax = self.vars.index(var)
        new_vars = tuple(v for v in self.vars if v != var)
        return _Factor(new_vars, _logsumexp(self.table, axis=ax))


def ve_marginals(n_vertices: int, edges: np.ndarray,
                 unary: Sequence[np.ndarray],
                 pairwise: Sequence[np.ndarray]) -> List[np.ndarray]:
    """Per-vertex marginals via repeated min-degree variable elimination."""
    base: List[_Factor] = []
    for v, u in enumerate(unary):
        base.append(_Factor((v,), np.log(np.asarray(u, dtype=np.float64))))
    for k, (i, j) in enumerate(np.asarray(edges)):
        i, j = int(i), int(j)
        base.append(_Factor((i, j),
                            np.log(np.asarray(pairwise[k], dtype=np.float64))))

    marginals: List[np.ndarray] = []
    for q in range(n_vertices):
        factors = list(base)
        remaining = set(range(n_vertices)) - {q}
        while remaining:
            # greedy: eliminate the variable whose product factor is smallest
            def cost(v: int) -> int:
                size = 1
                seen = set()
                for f in factors:
                    if v in f.vars:
                        for w, s in zip(f.vars, f.table.shape):
                            if w not in seen:
                                seen.add(w)
                                size *= s
                return size
            v = min(remaining, key=cost)
            remaining.discard(v)
            involved = [f for f in factors if v in f.vars]
            factors = [f for f in factors if v not in f.vars]
            if involved:
                prod = involved[0]
                for f in involved[1:]:
                    prod = prod.multiply(f)
                factors.append(prod.eliminate(v))
        prod = factors[0]
        for f in factors[1:]:
            prod = prod.multiply(f)
        assert prod.vars == (q,)
        t = prod.table - _logsumexp(prod.table, axis=0)
        marginals.append(np.exp(t))
    return marginals


def kl_divergence(p: np.ndarray, q: np.ndarray, eps: float = 1e-12) -> float:
    """KL(p || q) for two discrete distributions (paper Fig. 5 metric)."""
    p = np.clip(np.asarray(p, dtype=np.float64), eps, None)
    q = np.clip(np.asarray(q, dtype=np.float64), eps, None)
    p, q = p / p.sum(), q / q.sum()
    return float(np.sum(p * (np.log(p) - np.log(q))))
