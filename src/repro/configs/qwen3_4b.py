"""Qwen3-4B (dense, GQA kv=8, qk_norm). [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=9728, vocab=151936, mlp_act="silu", qk_norm=True, rope_theta=1e6,
    tie_embeddings=True,
)
