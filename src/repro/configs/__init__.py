from repro.configs.base import (ALL_SHAPES, ARCH_IDS, ArchConfig, InputShape,
                                all_configs, get,
                                TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)

__all__ = ["ALL_SHAPES", "ARCH_IDS", "ArchConfig", "InputShape",
           "all_configs", "get", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
           "LONG_500K"]
