"""DeepSeek-V3 671B (MLA + 1 shared + 256 routed top-8 + MTP).
[arXiv:2412.19437; hf]

Assigned d_ff=2048 is used for BOTH the routed/shared experts and the 3
dense lead-in layers (the released model uses 18432 for dense layers; we
stay literal to the assigned config -- recorded deviation)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=2048, vocab=129280, mlp_act="silu",
    n_experts=256, experts_per_token=8, n_shared_experts=1,
    n_dense_layers=3,
    mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_rope_dim=64, qk_nope_dim=128, v_head_dim=128,
    mtp=True,
)
