"""Hymba-1.5B (hybrid: parallel attention + mamba heads per layer, SWA).
[arXiv:2411.13676; hf]

Simplifications recorded in DESIGN.md: all layers use sliding-window
attention (the real model keeps 3 global layers + meta tokens and shares KV
cross-layer); the SSM branch runs at d_inner = d_model in parallel with the
attention branch, outputs averaged."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001, mlp_act="silu",
    hybrid=True, ssm_state=16, ssm_head_p=64, sliding_window=1024,
)
