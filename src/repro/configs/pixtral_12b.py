"""Pixtral-12B (vlm: pixtral-ViT frontend STUB + mistral-nemo backbone).
[hf:mistralai/Pixtral-12B-2409; unverified]

Per the assignment spec, only the transformer BACKBONE is modeled; the ViT
frontend is a stub -- input_specs() supplies precomputed patch embeddings
(n_frontend_tokens x d_model) that are prepended to the token sequence."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=131072, mlp_act="silu", rope_theta=1e6,
    frontend="vision", n_frontend_tokens=256,
)
