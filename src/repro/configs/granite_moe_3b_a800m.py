"""Granite-MoE 3B-a800m. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

Assigned config string specifies "MoE 40e top-8" while the margin note says
32 experts; we follow the explicit field (40 experts, top-8)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49155, mlp_act="silu",
    n_experts=40, experts_per_token=8, tie_embeddings=True,
)
