"""Whisper-medium (enc-dec audio backbone; conv frontend STUB).
[arXiv:2212.04356; unverified]

input_specs() supplies precomputed frame embeddings (B, S_enc, d_model) in
place of the conv1d+mel frontend. Encoder: bidirectional attention;
decoder: causal self-attn + cross-attn. LayerNorm + GELU (original arch),
learned positions approximated with RoPE=off / absolute embeddings."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab=51865, mlp_act="gelu", rope_theta=0.0,
    enc_dec=True, n_enc_layers=24, frontend="audio",
)
