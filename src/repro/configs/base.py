"""Architecture config schema, input shapes, and the registry.

Every assigned architecture is one ``<id>.py`` in this package exporting
``CONFIG``; ``repro.configs.get(name)`` loads it. ``reduced()`` produces the
CPU-smoke-test variant of the same family (tiny dims, same code paths).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

# ----------------------------------------------------------------- shapes --

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


# ----------------------------------------------------------------- config --

@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    # norm / act / rope
    mlp_act: str = "silu"        # silu = SwiGLU, gelu = GeGLU
    qk_norm: bool = False
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    n_dense_layers: int = 0      # leading dense layers (deepseek: 3)
    moe_dispatch: str = "ragged"  # ragged | dense | sharded (see layers/moe)
    # MLA (deepseek)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0
    mtp: bool = False            # multi-token-prediction aux head
    # SSM / hybrid
    ssm: bool = False            # attention-free (mamba2)
    hybrid: bool = False         # parallel attn+ssm heads (hymba)
    ssm_state: int = 0
    ssm_head_p: int = 64
    ssm_expand: int = 2
    sliding_window: int = 0      # hymba SWA
    # enc-dec (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    # modality frontend stub: "none" | "audio" | "vision"
    frontend: str = "none"
    n_frontend_tokens: int = 0   # vision: patches prepended to the sequence
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        if self.n_heads == 0:          # attention-free (mamba2)
            return 0
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so the unembedding shards over any
        power-of-two 'model' axis (logits are the largest activation; an
        unshardable vocab replicates them -- 13 GB/device at train_4k)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        if self.hybrid:
            return self.d_model          # parallel heads share width (hymba)
        return self.ssm_expand * self.d_model

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (see DESIGN.md SSArch-applicability)."""
        return self.ssm or self.hybrid

    @property
    def has_decoder(self) -> bool:
        return True   # every assigned arch decodes (whisper via its decoder)

    def shapes(self) -> Tuple[InputShape, ...]:
        out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
        if self.sub_quadratic:
            out.append(LONG_500K)
        return tuple(out)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16,
            d_ff=128, vocab=256,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            experts_per_token=(min(self.experts_per_token, 2)
                               if self.experts_per_token else 0),
            n_dense_layers=min(self.n_dense_layers, 1),
            q_lora_rank=32 if self.mla else 0,
            kv_lora_rank=16 if self.mla else 0,
            qk_rope_dim=8 if self.mla else 0,
            qk_nope_dim=16 if self.mla else 0,
            v_head_dim=16 if self.mla else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_p=16 if (self.ssm or self.hybrid) else 64,
            sliding_window=min(self.sliding_window, 32),
            n_enc_layers=min(self.n_enc_layers, 2),
            n_frontend_tokens=min(self.n_frontend_tokens, 8),
            dtype="float32",
        )


ARCH_IDS = (
    "mistral_large_123b", "gemma_7b", "starcoder2_3b", "qwen3_4b",
    "hymba_1_5b", "pixtral_12b", "whisper_medium", "granite_moe_3b_a800m",
    "deepseek_v3_671b", "mamba2_130m",
)


def get(name: str) -> ArchConfig:
    mod = importlib.import_module(
        f"repro.configs.{name.replace('-', '_')}")
    return mod.CONFIG


def all_configs():
    return {n: get(n) for n in ARCH_IDS}
