"""Mamba2-130M (attention-free SSD). [arXiv:2405.21060; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab=50280, ssm=True, ssm_state=128, ssm_head_p=64,
    ssm_expand=2,
)
