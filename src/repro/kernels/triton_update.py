"""Pallas GPU kernel ("triton" backend): fused BP message update, edge-major.

This is the paper's actual target -- many-core GPUs, one worker per edge --
lowered through Pallas's Triton path instead of hand CUDA. The layout
rethink is the *transpose* of the TPU kernel (``message_update.py``):

  * **edges on the grid axis, states in registers** -- a GPU has thousands
    of independent lanes, not one 128-wide vector unit, so the natural
    tiling is one Triton program per ``BLK_E``-edge tile with the (S,) and
    (S, S) state axes held entirely in registers/shared memory. Operands
    therefore stay in the engine's native edge-major layout, (E, S) /
    (E, S, S): the GPU path needs *zero* transposes at the boundary (the
    TPU path pays two per round to reach its (S, E) lane layout).
  * the whole per-edge pipeline after the vertex gather is **fused into one
    pass**: LSE- (or max-) propagate through the pairwise table, valid-state
    renormalize, and L-inf residual, so one HBM round-trip covers what the
    reference path does in three XLA fusions. The traffic contract is
    **3 reads + 2 writes per edge** (pairwise table, prelude, old messages
    in; new messages, residual out; plus the 1-byte dst-state mask), the
    model ``repro.roofline.kernel_model`` predicts from and
    ``tests/test_roofline.py`` pins.
  * **both semirings** ship in the same kernel skeleton: ``semiring="sum"``
    is sum-product (logsumexp propagate, LSE-normalize), ``semiring="max"``
    is max-product (max propagate, max-normalize) -- bit-compatible with
    ``repro.core.messages.max_product_update``, so the LDPC MAP workload
    runs the fused path too. Scheduling is semiring-agnostic (paper SSV).
  * padded state lanes carry ``dmask=0`` and contribute nothing; padded
    edges are all-masked and produce (NEG_INF messages, 0 residual) --
    masks are data, no divergent control flow. State counts are padded to
    the next power of two because Triton tiles (``tl.arange``) must be
    power-of-two sized; the pad lanes are dead weight the block picker
    accounts for.

Occupancy/tile budget: the (BLK_E, S, S) pairwise tile dominates the
working set at ``S^2 * BLK_E * itemsize`` bytes. ``pick_block_edges_gpu``
sizes BLK_E so one program's streamed working set stays under
``_GPU_WORKSET_BYTES`` (64 KiB -- two ``num_stages`` of that fit L1/SMEM on
any modern part), clamped to power-of-two [8, 1024]; at S >= 32 the
pairwise tile forces small blocks and low occupancy, exactly as the TPU
VMEM budget does. ``autotune_blk_e`` measures candidates around that
prediction; ``benchmarks/bench_kernel.py`` records predicted-vs-measured
arithmetic intensity per scheduler into ``BENCH_kernel.json``.

Off-GPU the kernel runs in ``interpret=True`` mode (CPU CI exercises the
same program through the Pallas interpreter), so ``BPConfig(
backend="triton")`` is usable -- and differentially tested against the
reference path -- everywhere; on a CUDA device the identical program lowers
through Triton with ``plgpu.CompilerParams`` (num_warps scaled to the
tile, ``num_stages=2`` for double-buffered HBM streaming).
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # GPU lowering knobs; absent/renamed on CPU-only or old installs.
    from jax.experimental.pallas import triton as plgpu
    _TRITON_PARAMS = getattr(plgpu, "TritonCompilerParams",
                             getattr(plgpu, "CompilerParams", None))
except Exception:  # pragma: no cover - environment-dependent
    plgpu = None
    _TRITON_PARAMS = None

NEG_INF = -1.0e30
_GPU_WORKSET_BYTES = 64 * 1024
_MIN_BLK = 8
_MAX_BLK = 1024

__all__ = ["fused_update_e", "pick_block_edges_gpu", "autotune_blk_e",
           "next_pow2"]


def next_pow2(n: int) -> int:
    """Smallest power of two >= ``n`` (>= 1) -- Triton tile sizes and the
    state-padding width must be power-of-two."""
    n = max(int(n), 1)
    return 1 << (n - 1).bit_length()


def pick_block_edges_gpu(n_states: int, dtype_bytes: int = 4, *,
                         budget: int = _GPU_WORKSET_BYTES) -> int:
    """Largest power-of-two edge block whose streamed working set fits the
    per-program budget.

    Working set per edge ~ (S^2 + 4S + 2) * itemsize -- the 3-read/2-write
    fusion model (pairwise table + prelude/old/new message rows + mask +
    residual), same accounting as the TPU picker but against a GPU
    SMEM/L1-scale budget and power-of-two blocks (Triton tile constraint).
    Result is clamped to [8, 1024]: >=8 keeps tiles warp-friendly, <=1024
    keeps a single program's register demand sane.
    """
    per_edge = (n_states * n_states + 4 * n_states + 2) * max(dtype_bytes, 1)
    blk = max(int(budget) // per_edge, 1)
    blk = 1 << (blk.bit_length() - 1)          # floor to power of two
    return int(min(max(blk, _MIN_BLK), _MAX_BLK))


def _sum_kernel(logpsi_ref, pre_ref, logm_ref, dmask_ref, out_ref, resid_ref):
    """Blocks: logpsi (Eb,S,S) [e,xi,xj]; pre/logm/dmask/out (Eb,S); resid (Eb,).

    Sum-product: LSE over source states (max-shift for stability), then
    LSE-renormalize over valid destination states, then L-inf residual.
    Mirrors ``message_update._fused_kernel`` with every axis transposed.
    """
    scores = logpsi_ref[...] + pre_ref[...][:, :, None]      # (Eb,S,S)
    m = jnp.maximum(jnp.max(scores, axis=1), NEG_INF)        # (Eb,S) over xi
    s = jnp.sum(jnp.exp(scores - m[:, None, :]), axis=1)
    cand = m + jnp.log(jnp.maximum(s, 1e-38))                # (Eb,S) [e,xj]
    dmask = dmask_ref[...] != 0
    cand = jnp.where(dmask, cand, NEG_INF)
    zm = jnp.maximum(jnp.max(cand, axis=1), NEG_INF)         # (Eb,)
    zs = jnp.sum(jnp.where(dmask, jnp.exp(cand - zm[:, None]), 0.0), axis=1)
    z = zm + jnp.log(jnp.maximum(zs, 1e-38))
    new = jnp.where(dmask, cand - z[:, None], NEG_INF)
    out_ref[...] = new
    resid_ref[...] = jnp.max(
        jnp.where(dmask, jnp.abs(new - logm_ref[...]), 0.0), axis=1)


def _max_kernel(logpsi_ref, pre_ref, logm_ref, dmask_ref, out_ref, resid_ref):
    """Max-product semiring: max-propagate + max-normalize (peak at 0 over
    valid states), matching ``repro.core.messages.max_product_update``
    exactly -- max reductions are order-exact, so parity is bitwise."""
    scores = logpsi_ref[...] + pre_ref[...][:, :, None]      # (Eb,S,S)
    cand = jnp.max(scores, axis=1)                           # (Eb,S) over xi
    dmask = dmask_ref[...] != 0
    cand = jnp.where(dmask, cand, NEG_INF)
    z = jnp.max(cand, axis=1)                                # (Eb,)
    new = jnp.where(dmask, cand - z[:, None], NEG_INF)
    out_ref[...] = new
    resid_ref[...] = jnp.max(
        jnp.where(dmask, jnp.abs(new - logm_ref[...]), 0.0), axis=1)


_KERNELS = {"sum": _sum_kernel, "max": _max_kernel}


def _compiler_params(blk: int, s_pad: int):
    """Triton launch knobs for the non-interpret (real GPU) path: warps
    scaled to the (BLK_E, S) tile, 2 stages for double-buffered streaming."""
    if _TRITON_PARAMS is None:  # pragma: no cover - environment-dependent
        return None
    warps = next_pow2(min(8, max(1, (blk * s_pad) // 2048)))
    return _TRITON_PARAMS(num_warps=int(warps), num_stages=2)


@functools.partial(jax.jit,
                   static_argnames=("semiring", "interpret", "blk_e"))
def fused_update_e(logpsi: jax.Array,   # (E, S, S) [e, x_src, x_dst]
                   pre: jax.Array,      # (E, S) source-side belief
                   logm: jax.Array,     # (E, S) current messages
                   dmask: jax.Array,    # (E, S) bool-ish valid dst states
                   *, semiring: str = "sum", interpret: bool = False,
                   blk_e: int | None = None):
    """Fused gather->propagate->normalize->residual update, edge-major.

    Returns ``(new_logm (E, S), residual (E,))``. States are padded to the
    next power of two and edges to a multiple of ``BLK_E`` internally; pad
    lanes are all-masked and inert (NEG_INF messages, zero residual).
    ``semiring`` is ``"sum"`` (sum-product) or ``"max"`` (max-product);
    ``blk_e`` overrides the roofline-model block picker (autotuning hook).
    """
    if semiring not in _KERNELS:
        raise ValueError(f"unknown semiring {semiring!r}; "
                         f"expected one of {sorted(_KERNELS)}")
    e, s = pre.shape
    dtype_bytes = jnp.dtype(pre.dtype).itemsize
    s_pad = max(2, next_pow2(s))
    if s_pad != s:
        d = s_pad - s
        logpsi = jnp.pad(logpsi, ((0, 0), (0, d), (0, d)))
        pre = jnp.pad(pre, ((0, 0), (0, d)), constant_values=NEG_INF)
        logm = jnp.pad(logm, ((0, 0), (0, d)), constant_values=NEG_INF)
        dmask = jnp.pad(dmask, ((0, 0), (0, d)))
    blk = blk_e or pick_block_edges_gpu(s_pad, dtype_bytes)
    blk = max(_MIN_BLK, min(blk, next_pow2(e)))
    e_pad = ((e + blk - 1) // blk) * blk
    if e_pad != e:
        d = e_pad - e
        logpsi = jnp.pad(logpsi, ((0, d), (0, 0), (0, 0)))
        pre = jnp.pad(pre, ((0, d), (0, 0)), constant_values=NEG_INF)
        logm = jnp.pad(logm, ((0, d), (0, 0)), constant_values=NEG_INF)
        dmask = jnp.pad(dmask, ((0, d), (0, 0)))
    grid = (e_pad // blk,)
    kwargs = {}
    if not interpret:  # pragma: no cover - requires a CUDA device
        params = _compiler_params(blk, s_pad)
        if params is not None:
            kwargs["compiler_params"] = params
    new, resid = pl.pallas_call(
        _KERNELS[semiring],
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, s_pad, s_pad), lambda i: (i, 0, 0)),
            pl.BlockSpec((blk, s_pad), lambda i: (i, 0)),
            pl.BlockSpec((blk, s_pad), lambda i: (i, 0)),
            pl.BlockSpec((blk, s_pad), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk, s_pad), lambda i: (i, 0)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((e_pad, s_pad), pre.dtype),
            jax.ShapeDtypeStruct((e_pad,), pre.dtype),
        ],
        interpret=interpret,
        **kwargs,
    )(logpsi, pre, logm, dmask.astype(jnp.int8))
    return new[:e, :s], resid[:e]


def autotune_blk_e(logpsi, pre, logm, dmask, *, semiring: str = "sum",
                   interpret: bool = True, candidates=None, iters: int = 3):
    """Measure ``fused_update_e`` wall time per power-of-two block size and
    return ``(best_blk, {blk: mean_us})``.

    Candidates default to the powers of two from 8 up to the roofline
    picker's choice x4 (the model is a lower-bound traffic estimate, so the
    measured optimum may sit above it). On CPU this times the interpreter
    -- machinery exercise, not a GPU claim; on a CUDA device it times the
    Triton lowering for real. ``bench_kernel`` records both the model pick
    and the measured pick so drift is visible.
    """
    e, s = pre.shape
    s_pad = max(2, next_pow2(s))
    model = pick_block_edges_gpu(s_pad, jnp.dtype(pre.dtype).itemsize)
    if candidates is None:
        hi = min(_MAX_BLK, next_pow2(e), model * 4)
        candidates, c = [], _MIN_BLK
        while c <= hi:
            candidates.append(c)
            c *= 2
    timings = {}
    for blk in candidates:
        out = fused_update_e(logpsi, pre, logm, dmask, semiring=semiring,
                             interpret=interpret, blk_e=blk)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fused_update_e(logpsi, pre, logm, dmask, semiring=semiring,
                                 interpret=interpret, blk_e=blk)
            jax.block_until_ready(out)
        timings[blk] = (time.perf_counter() - t0) / iters * 1e6
    best = min(timings, key=timings.get)
    return best, timings
