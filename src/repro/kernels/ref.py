"""Pure-jnp oracles for the fused message-update kernels.

``fused_update_t_ref`` mirrors ``message_update.fused_update_t`` exactly
(same transposed (S, E) layout, same masking/normalization semantics);
``fused_update_e_ref`` mirrors ``triton_update.fused_update_e`` in the
GPU-native edge-major (E, S) layout for both semirings. Tests
assert_allclose against these across shape/dtype/semiring sweeps. The
underlying math also lives in ``repro.core.messages``; this module is the
kernel-layout contract.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def fused_update_t_ref(logpsi_t: jax.Array,   # (S, S, E)
                       pre_t: jax.Array,      # (S, E)
                       logm_t: jax.Array,     # (S, E)
                       dmask_t: jax.Array):   # (S, E) bool-ish
    scores = logpsi_t + pre_t[:, None, :]
    m = jnp.maximum(jnp.max(scores, axis=0), NEG_INF)
    s = jnp.sum(jnp.exp(scores - m[None]), axis=0)
    cand = m + jnp.log(jnp.maximum(s, 1e-38))
    dmask = dmask_t != 0
    cand = jnp.where(dmask, cand, NEG_INF)
    zm = jnp.maximum(jnp.max(cand, axis=0), NEG_INF)
    zs = jnp.sum(jnp.where(dmask, jnp.exp(cand - zm[None]), 0.0), axis=0)
    z = zm + jnp.log(jnp.maximum(zs, 1e-38))
    new = jnp.where(dmask, cand - z[None], NEG_INF)
    resid = jnp.max(jnp.where(dmask, jnp.abs(new - logm_t), 0.0), axis=0)
    return new, resid


def fused_update_e_ref(logpsi: jax.Array,   # (E, S, S)
                       pre: jax.Array,      # (E, S)
                       logm: jax.Array,     # (E, S)
                       dmask: jax.Array,    # (E, S) bool-ish
                       *, semiring: str = "sum"):
    """Edge-major oracle for ``triton_update.fused_update_e`` (both
    semirings). ``semiring="max"`` reproduces ``max_product_update``'s
    max-normalize; ``"sum"`` the LSE pipeline of ``fused_update_t_ref``."""
    scores = logpsi + pre[:, :, None]
    dmask = dmask != 0
    if semiring == "max":
        cand = jnp.max(scores, axis=1)
        cand = jnp.where(dmask, cand, NEG_INF)
        z = jnp.max(cand, axis=1)
        new = jnp.where(dmask, cand - z[:, None], NEG_INF)
    else:
        m = jnp.maximum(jnp.max(scores, axis=1), NEG_INF)
        s = jnp.sum(jnp.exp(scores - m[:, None, :]), axis=1)
        cand = m + jnp.log(jnp.maximum(s, 1e-38))
        cand = jnp.where(dmask, cand, NEG_INF)
        zm = jnp.maximum(jnp.max(cand, axis=1), NEG_INF)
        zs = jnp.sum(jnp.where(dmask, jnp.exp(cand - zm[:, None]), 0.0),
                     axis=1)
        z = zm + jnp.log(jnp.maximum(zs, 1e-38))
        new = jnp.where(dmask, cand - z[:, None], NEG_INF)
    resid = jnp.max(jnp.where(dmask, jnp.abs(new - logm), 0.0), axis=1)
    return new, resid
