"""Pure-jnp oracle for the fused message-update kernel.

Mirrors ``message_update.fused_update_t`` exactly (same transposed layout,
same masking/normalization semantics) so tests can assert_allclose across
shape/dtype sweeps. The underlying math also lives in ``repro.core.messages``
in (E, S) layout; this module is the kernel-layout contract.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def fused_update_t_ref(logpsi_t: jax.Array,   # (S, S, E)
                       pre_t: jax.Array,      # (S, E)
                       logm_t: jax.Array,     # (S, E)
                       dmask_t: jax.Array):   # (S, E) bool-ish
    scores = logpsi_t + pre_t[:, None, :]
    m = jnp.maximum(jnp.max(scores, axis=0), NEG_INF)
    s = jnp.sum(jnp.exp(scores - m[None]), axis=0)
    cand = m + jnp.log(jnp.maximum(s, 1e-38))
    dmask = dmask_t != 0
    cand = jnp.where(dmask, cand, NEG_INF)
    zm = jnp.maximum(jnp.max(cand, axis=0), NEG_INF)
    zs = jnp.sum(jnp.where(dmask, jnp.exp(cand - zm[None]), 0.0), axis=0)
    z = zm + jnp.log(jnp.maximum(zs, 1e-38))
    new = jnp.where(dmask, cand - z[None], NEG_INF)
    resid = jnp.max(jnp.where(dmask, jnp.abs(new - logm_t), 0.0), axis=0)
    return new, resid
