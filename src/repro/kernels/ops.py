"""Jit'd public wrappers around the Pallas message-update kernel.

``pallas_update(pgm, logm)`` is a drop-in replacement for
``repro.core.messages.ref_update`` (same (E, S) layout at the boundary); it
handles the transpose to kernel layout, edge padding to the block size, and
interpret-mode fallback off-TPU.

``pallas_update_t`` is the layout-native variant used by the perf-tuned BP
loop, which keeps messages transposed (S, E) across rounds so the two
transposes per round disappear (see EXPERIMENTS.md SSPerf, BP iterations).

``pallas_update_batch`` is the bucket path: a ``BatchedPGM``'s (B, E) edges
are folded into one (B*E,) edge axis so a single kernel launch -- one
``pallas_call`` grid of B*E / BLK_E blocks -- covers the whole bucket,
instead of B separate launches (or a vmap-added grid dimension with
per-graph remainder waste). ``make_pallas_update_batch`` packages it as a
``batch_update_fn`` for ``repro.core.batch.run_bp_batch``.

``triton_update`` / ``triton_update_batch`` are the GPU-class equivalents
(``repro.kernels.triton_update``): same fused pipeline in the engine's
native edge-major layout (zero boundary transposes), blocked over edges
with states in registers, lowered through Pallas's Triton path on CUDA
devices and through the interpreter everywhere else -- plus a
``semiring="max"`` mode so MAP workloads run fused too. Registered as
``"triton"`` in both registries; ``BPConfig(backend="triton")`` reaches it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import messages as M
from repro.core.graph import PGM
from repro.core.registry import Registry
from repro.kernels.message_update import fused_update_t, pick_block_edges
from repro.kernels.triton_update import fused_update_e

__all__ = ["UPDATE_BACKENDS", "BATCH_UPDATE_BACKENDS", "kernel_operands_t",
           "pallas_update", "make_pallas_update", "pallas_update_batch",
           "make_pallas_update_batch", "triton_update", "make_triton_update",
           "triton_update_batch", "make_triton_update_batch",
           "register_update_backend", "list_backends", "get_update_fn"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _on_gpu() -> bool:
    return jax.default_backend() == "gpu"


def kernel_operands_t(pgm: PGM):
    """Precompute the static transposed operands (do once per graph)."""
    logpsi_t = jnp.transpose(pgm.log_psi_e, (1, 2, 0))      # (S, S, E)
    dmask_t = pgm.state_mask[pgm.edge_dst].T                # (S, E)
    return logpsi_t, dmask_t


@functools.partial(jax.jit, static_argnames=("interpret",))
def pallas_update(pgm: PGM, logm: jax.Array, *, interpret: bool | None = None):
    """(cand (E,S), resid (E,)) -- kernel-backed ref_update equivalent."""
    if interpret is None:
        interpret = not _on_tpu()
    pre = M.edge_prelude(pgm, logm)                          # (E, S)
    logpsi_t, dmask_t = kernel_operands_t(pgm)
    new_t, resid = fused_update_t(
        logpsi_t, pre.T, logm.T, dmask_t, interpret=interpret)
    return new_t.T, resid


def make_pallas_update(interpret: bool | None = None):
    """Static-arg-free closure suitable for ``run_bp(update_fn=...)``."""
    if interpret is None:
        interpret = not _on_tpu()

    def update_fn(pgm: PGM, logm: jax.Array):
        return pallas_update(pgm, logm, interpret=interpret)

    return update_fn


@functools.partial(jax.jit, static_argnames=("interpret",))
def pallas_update_batch(bpgm: PGM, logm: jax.Array, *,
                        interpret: bool | None = None):
    """(cand (B,E,S), resid (B,E)) over a stacked element-PGM whose leaves
    carry a leading batch axis (``BatchedPGM.pgm``). The batch axis is folded
    into the kernel's edge axis: one launch, grid = ceil(B*E / BLK_E).
    """
    if interpret is None:
        interpret = not _on_tpu()
    b, e, s = logm.shape
    pre = jax.vmap(M.edge_prelude)(bpgm, logm)                # (B, E, S)
    # Fold batch into edges: graph b's edge e becomes folded edge b*E + e.
    logpsi_t = jnp.transpose(bpgm.log_psi_e.reshape(b * e, s, s), (1, 2, 0))
    dmask = jax.vmap(lambda p: p.state_mask[p.edge_dst])(bpgm)
    dmask_t = dmask.reshape(b * e, s).T                       # (S, B*E)
    new_t, resid = fused_update_t(
        logpsi_t, pre.reshape(b * e, s).T, logm.reshape(b * e, s).T,
        dmask_t, interpret=interpret)
    return new_t.T.reshape(b, e, s), resid.reshape(b, e)


def make_pallas_update_batch(interpret: bool | None = None):
    """``batch_update_fn`` closure for the engine's batched path: whole-
    bucket fused message update in one kernel launch."""
    if interpret is None:
        interpret = not _on_tpu()

    def batch_update_fn(bpgm: PGM, logm: jax.Array):
        return pallas_update_batch(bpgm, logm, interpret=interpret)

    return batch_update_fn


# ------------------------------------------------- triton (GPU) backend --

@functools.partial(jax.jit, static_argnames=("interpret", "semiring",
                                             "blk_e"))
def triton_update(pgm: PGM, logm: jax.Array, *, interpret: bool | None = None,
                  semiring: str = "sum", blk_e: int | None = None):
    """(cand (E,S), resid (E,)) -- GPU-kernel-backed ``ref_update`` (or, with
    ``semiring="max"``, ``max_product_update``) equivalent. Edge-major all
    the way: no layout transposes at the boundary."""
    if interpret is None:
        interpret = not _on_gpu()
    pre = M.edge_prelude(pgm, logm)                          # (E, S)
    dmask = pgm.state_mask[pgm.edge_dst]                     # (E, S)
    return fused_update_e(pgm.log_psi_e, pre, logm, dmask,
                          semiring=semiring, interpret=interpret,
                          blk_e=blk_e)


def make_triton_update(interpret: bool | None = None, *,
                       semiring: str = "sum", blk_e: int | None = None):
    """Static-arg-free closure for ``BPConfig(backend="triton")``: resolves
    ``interpret`` once (Triton lowering on GPU, interpreter elsewhere) so
    the returned callable is jit-cache-stable."""
    if interpret is None:
        interpret = not _on_gpu()

    def update_fn(pgm: PGM, logm: jax.Array):
        return triton_update(pgm, logm, interpret=interpret,
                             semiring=semiring, blk_e=blk_e)

    return update_fn


@functools.partial(jax.jit, static_argnames=("interpret", "semiring",
                                             "blk_e"))
def triton_update_batch(bpgm: PGM, logm: jax.Array, *,
                        interpret: bool | None = None, semiring: str = "sum",
                        blk_e: int | None = None):
    """(cand (B,E,S), resid (B,E)) bucket path: the batch axis folds into
    the kernel's edge grid (one launch of ceil(B*E / BLK_E) programs), same
    fold as ``pallas_update_batch`` but with zero transposes."""
    if interpret is None:
        interpret = not _on_gpu()
    b, e, s = logm.shape
    pre = jax.vmap(M.edge_prelude)(bpgm, logm)                # (B, E, S)
    dmask = jax.vmap(lambda p: p.state_mask[p.edge_dst])(bpgm)
    new, resid = fused_update_e(
        bpgm.log_psi_e.reshape(b * e, s, s), pre.reshape(b * e, s),
        logm.reshape(b * e, s), dmask.reshape(b * e, s),
        semiring=semiring, interpret=interpret, blk_e=blk_e)
    return new.reshape(b, e, s), resid.reshape(b, e)


def make_triton_update_batch(interpret: bool | None = None, *,
                             semiring: str = "sum", blk_e: int | None = None):
    """``batch_update_fn`` closure: whole-bucket fused edge-major update in
    one kernel launch (the ``"triton"`` batched registry entry)."""
    if interpret is None:
        interpret = not _on_gpu()

    def batch_update_fn(bpgm: PGM, logm: jax.Array):
        return triton_update_batch(bpgm, logm, interpret=interpret,
                                   semiring=semiring, blk_e=blk_e)

    return batch_update_fn


# ------------------------------------------------- backend registry ------
# Message-update backends addressable by BPConfig.backend string. "ref" is
# the pure-jnp oracle; "pallas" the fused kernel (interpret-mode off-TPU).
# Batched entries return a natively batched (B, E, S) update; the engine's
# default batched path instead folds the bucket and reuses the single-graph
# backend, so only register a batched entry when it beats the fold.

def _make_sharded_update(**kwargs):
    # Lazy import: repro.dist builds on the engine, which resolves backends
    # through this registry -- importing at call time breaks the cycle.
    from repro.dist import make_sharded_update
    return make_sharded_update(**kwargs)


#: name -> zero/kwarg factory returning an ``update_fn``. A ``Registry``
#: (dict subclass): plain-dict reads keep working.
UPDATE_BACKENDS = Registry("update backend", {
    "ref": lambda: M.ref_update,
    # Max-product (MAP) semiring: scheduling is semiring-agnostic (paper
    # SSV), so swapping the update swaps the inference task -- the LDPC
    # decoding workload serves through the unchanged engine/serving stack
    # with BPConfig(backend="maxprod") and map_assignment on the result.
    "maxprod": lambda: M.max_product_update,
    "pallas": make_pallas_update,
    # GPU-class fused kernel (Pallas Triton lowering, edge-major blocks,
    # states in registers; interpret-mode everywhere off-GPU so CPU CI
    # exercises the same program). semiring="max" kwarg serves MAP.
    "triton": make_triton_update,
    # Multi-device shard_map update over the edge axis (repro.dist). With
    # no kwargs a mesh over all devices is built at resolve time, so
    # BPConfig(backend="sharded") stays a serializable string. The edge
    # axis must split evenly over the mesh (padded counts are multiples of
    # 128, so power-of-two meshes <= 64 always work); run_bp_sharded
    # re-pads single graphs that don't.
    "sharded": _make_sharded_update,
})

BATCH_UPDATE_BACKENDS = Registry("batched update backend", {
    "pallas": make_pallas_update_batch,
    "triton": make_triton_update_batch,
})


def register_update_backend(name: str, *, batched: bool = False,
                            overwrite: bool = False):
    """Decorator registering an update-backend factory under ``name``
    (lowercased). Duplicates raise ``ValueError`` unless ``overwrite=True``."""
    registry = BATCH_UPDATE_BACKENDS if batched else UPDATE_BACKENDS
    return registry.register(name, overwrite=overwrite)


def list_backends(*, batched: bool = False):
    """Sorted registered backend names (valid ``BPConfig.backend`` specs)."""
    return (BATCH_UPDATE_BACKENDS if batched else UPDATE_BACKENDS).names()


def get_update_fn(name: str, *, batched: bool = False, **kwargs):
    """Resolve a backend name to an update callable (see registries above).
    ``kwargs`` (e.g. ``interpret=``) pass through to the factory."""
    registry = BATCH_UPDATE_BACKENDS if batched else UPDATE_BACKENDS
    return registry.lookup(name)(**kwargs)
