"""Jit'd public wrappers around the Pallas message-update kernel.

``pallas_update(pgm, logm)`` is a drop-in replacement for
``repro.core.messages.ref_update`` (same (E, S) layout at the boundary); it
handles the transpose to kernel layout, edge padding to the block size, and
interpret-mode fallback off-TPU.

``pallas_update_t`` is the layout-native variant used by the perf-tuned BP
loop, which keeps messages transposed (S, E) across rounds so the two
transposes per round disappear (see EXPERIMENTS.md SSPerf, BP iterations).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import messages as M
from repro.core.graph import PGM
from repro.kernels.message_update import fused_update_t, pick_block_edges


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def kernel_operands_t(pgm: PGM):
    """Precompute the static transposed operands (do once per graph)."""
    logpsi_t = jnp.transpose(pgm.log_psi_e, (1, 2, 0))      # (S, S, E)
    dmask_t = pgm.state_mask[pgm.edge_dst].T                # (S, E)
    return logpsi_t, dmask_t


@functools.partial(jax.jit, static_argnames=("interpret",))
def pallas_update(pgm: PGM, logm: jax.Array, *, interpret: bool | None = None):
    """(cand (E,S), resid (E,)) -- kernel-backed ref_update equivalent."""
    if interpret is None:
        interpret = not _on_tpu()
    pre = M.edge_prelude(pgm, logm)                          # (E, S)
    logpsi_t, dmask_t = kernel_operands_t(pgm)
    new_t, resid = fused_update_t(
        logpsi_t, pre.T, logm.T, dmask_t, interpret=interpret)
    return new_t.T, resid


def make_pallas_update(interpret: bool | None = None):
    """Static-arg-free closure suitable for ``run_bp(update_fn=...)``."""
    if interpret is None:
        interpret = not _on_tpu()

    def update_fn(pgm: PGM, logm: jax.Array):
        return pallas_update(pgm, logm, interpret=interpret)

    return update_fn
