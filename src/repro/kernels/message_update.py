"""Pallas TPU kernel: fused BP message update + normalize + residual.

This is the paper's per-round compute hot spot (SS III-B "Update" kernel).
The CUDA version assigns one thread per edge; the TPU-native rethink is:

  * **edges on the 128-wide lane axis** -- state counts are 2..96, far below
    the lane width, so an (E, S) row-major layout would waste >90% of every
    vector register. All kernel operands are stored transposed, (S, E) /
    (S, S, E), with E tiled by ``BlockSpec`` along the grid.
  * the whole per-edge pipeline after the vertex gather is **fused into one
    VMEM-resident pass**: LSE-propagate through the pairwise table,
    destination-state renormalize, and L-inf residual, so candidate messages
    are produced in a single HBM round-trip (3 reads, 2 writes per edge
    block) instead of the 3 separate XLA fusions the reference path emits.
  * the LSE over source states runs on sublanes (VPU reduction), with the
    max-shift trick for stability; padded states carry NEG_INF and padded
    edges point at a 1-state dummy vertex, so no divergent control flow is
    needed -- masks are data, exactly as on the GPU.

VMEM budget: the (S, S, BLK_E) pairwise block dominates at
S^2 * BLK_E * 4 B; ``pick_block_edges`` sizes BLK_E so the working set stays
under ~4 MiB (one core's VMEM is 16 MiB on v5e; we leave room for
double-buffering of in/out streams).

The kernel is batch-agnostic by construction: edges are an opaque 1-D grid
axis, so a *bucket* of B same-shape graphs is served by folding the batch
axis into the edge axis (E -> B*E, see ``repro.kernels.ops.
pallas_update_batch``) -- one launch, full lane occupancy across graph
boundaries, no per-graph block-remainder waste.

Validated in ``interpret=True`` mode on CPU against ``ref.py`` (pure jnp).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1.0e30
_LANE = 128
_VMEM_BUDGET_BYTES = 4 * 1024 * 1024


def pick_block_edges(n_states: int, dtype_bytes: int = 4) -> int:
    """Largest lane-multiple edge block whose working set fits the budget.

    Working set per block ~ (S^2 + 4*S + 2) * BLK_E * dtype_bytes
    (pairwise table + pre/old/new/dst-mask rows + residual row).
    """
    per_edge = (n_states * n_states + 4 * n_states + 2) * dtype_bytes
    blk = _VMEM_BUDGET_BYTES // max(per_edge, 1)
    blk = max(_LANE, (blk // _LANE) * _LANE)
    return int(min(blk, 4096))


def _fused_kernel(logpsi_ref, pre_ref, logm_ref, dmask_ref,
                  out_ref, resid_ref):
    """Blocks: logpsi (S,S,Eb) [xi,xj,e]; pre/logm/dmask/out (S,Eb); resid (1,Eb)."""
    scores = logpsi_ref[...] + pre_ref[...][:, None, :]      # (S,S,Eb)
    m = jnp.maximum(jnp.max(scores, axis=0), NEG_INF)        # (S,Eb) over xi
    s = jnp.sum(jnp.exp(scores - m[None, :, :]), axis=0)
    cand = m + jnp.log(jnp.maximum(s, 1e-38))                # (S,Eb) [xj,e]
    dmask = dmask_ref[...] != 0
    cand = jnp.where(dmask, cand, NEG_INF)
    # renormalize over valid destination states (sublane reduction)
    zm = jnp.maximum(jnp.max(cand, axis=0), NEG_INF)         # (Eb,)
    zs = jnp.sum(jnp.where(dmask, jnp.exp(cand - zm[None, :]), 0.0), axis=0)
    z = zm + jnp.log(jnp.maximum(zs, 1e-38))
    new = jnp.where(dmask, cand - z[None, :], NEG_INF)
    out_ref[...] = new
    resid_ref[...] = jnp.max(
        jnp.where(dmask, jnp.abs(new - logm_ref[...]), 0.0),
        axis=0)[None, :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_update_t(logpsi_t: jax.Array,   # (S, S, E) [x_src, x_dst, e]
                   pre_t: jax.Array,      # (S, E) source-side belief
                   logm_t: jax.Array,     # (S, E) current messages
                   dmask_t: jax.Array,    # (S, E) int8/bool valid dst states
                   *, interpret: bool = False):
    """Returns (new_logm_t (S, E), residual (E,)). Edges are padded to the
    block size internally (padded lanes carry all-masked states -> inert)."""
    s, e = pre_t.shape
    # Size blocks for the *actual* operand width: bf16 operands halve the
    # per-edge working set, so the VMEM budget admits twice the edges.
    blk = min(pick_block_edges(s, jnp.dtype(pre_t.dtype).itemsize),
              max(_LANE, e))
    e_pad = ((e + blk - 1) // blk) * blk
    if e_pad != e:
        pad = [(0, 0)] * (len(logpsi_t.shape) - 1) + [(0, e_pad - e)]
        logpsi_t = jnp.pad(logpsi_t, pad)
        pre_t = jnp.pad(pre_t, ((0, 0), (0, e_pad - e)),
                        constant_values=NEG_INF)
        logm_t = jnp.pad(logm_t, ((0, 0), (0, e_pad - e)),
                         constant_values=NEG_INF)
        dmask_t = jnp.pad(dmask_t, ((0, 0), (0, e_pad - e)))
    grid = (e_pad // blk,)
    new_t, resid = pl.pallas_call(
        _fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s, s, blk), lambda i: (0, 0, i)),
            pl.BlockSpec((s, blk), lambda i: (0, i)),
            pl.BlockSpec((s, blk), lambda i: (0, i)),
            pl.BlockSpec((s, blk), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((s, blk), lambda i: (0, i)),
            pl.BlockSpec((1, blk), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, e_pad), pre_t.dtype),
            jax.ShapeDtypeStruct((1, e_pad), pre_t.dtype),
        ],
        interpret=interpret,
    )(logpsi_t, pre_t, logm_t, dmask_t.astype(jnp.int8))
    return new_t[:, :e], resid[0, :e]
