from repro.ft.resilience import (ElasticMesh, StragglerMonitor,
                                 run_bp_resilient)

__all__ = ["ElasticMesh", "StragglerMonitor", "run_bp_resilient"]
