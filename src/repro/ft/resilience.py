"""Fault tolerance & straggler mitigation.

Three mechanisms, matched to how BP and LM training actually fail at pod
scale:

1. **ElasticMesh** -- a mesh factory that re-lowers on device-count change.
   Checkpoints are mesh-agnostic (repro.checkpoint stores host arrays), so
   a job that loses a pod restarts on the surviving devices: reload the
   last step, rebuild the mesh from whatever ``jax.devices()`` now reports,
   re-lower. The dry-run exercises 256- and 512-chip meshes from the same
   code path, which is exactly this contract.

2. **StragglerMonitor** -- per-round wall-time EWMA with an outlier budget.
   At the driver level a round that exceeds ``budget_factor`` x EWMA marks
   a straggler event; the driver's response is workload-specific (BP:
   continue -- stale messages are *correct* under asynchronous BP semantics,
   the paper's own argument; training: flag the step for the health log and
   optionally skip the optimizer commit).

3. **run_bp_resilient** -- chunked BP execution: instead of one unbounded
   ``while_loop``, run ``rounds_per_chunk`` at a time, checkpoint
   (messages, scheduler state, round) between chunks, and resume from the
   last chunk on crash. Convergence is monotone in useful work, so chunked
   restart loses at most one chunk of progress.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_pytree, save_pytree
from repro.core import messages as M
from repro.core.graph import PGM
from repro.core.runner import run_bp


class ElasticMesh:
    """Rebuilds the (data, model)-style mesh from live devices."""

    def __init__(self, model_parallel: int = 1, axis_names=("data", "model")):
        self.model_parallel = model_parallel
        self.axis_names = axis_names
        self._n = 0

    def current(self):
        devs = jax.devices()
        n = len(devs)
        mp = min(self.model_parallel, n)
        while n % mp:
            mp -= 1
        self._n = n
        return jax.make_mesh((n // mp, mp), self.axis_names, devices=devs)

    def changed(self) -> bool:
        return len(jax.devices()) != self._n


@dataclasses.dataclass
class StragglerMonitor:
    budget_factor: float = 3.0
    alpha: float = 0.2
    ewma: float = 0.0
    events: int = 0
    rounds: int = 0

    def record(self, wall_s: float) -> bool:
        """Returns True if this round was a straggler."""
        self.rounds += 1
        if self.ewma == 0.0:
            self.ewma = wall_s
            return False
        straggler = wall_s > self.budget_factor * self.ewma
        if straggler:
            self.events += 1
        else:  # don't poison the EWMA with outliers
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * wall_s
        return straggler


def run_bp_resilient(pgm: PGM, scheduler, rng: jax.Array, *,
                     eps: float = 1e-3, max_rounds: int = 4000,
                     rounds_per_chunk: int = 200,
                     ckpt_dir: Optional[str] = None,
                     monitor: Optional[StragglerMonitor] = None):
    """Chunked, checkpointed BP. Returns the same BPResult as run_bp.

    Resumes from ``ckpt_dir`` if it holds a newer chunk (crash recovery).
    """
    logm = M.init_messages(pgm)
    sstate = scheduler.init(pgm)
    done_rounds = 0
    if ckpt_dir is not None and (step := latest_step(ckpt_dir)) is not None:
        like = {"logm": logm, "sstate": sstate}
        restored, extra = restore_pytree(ckpt_dir, step, like)
        logm, sstate = restored["logm"], restored["sstate"]
        done_rounds = int(extra["rounds"])
    result = None
    while done_rounds < max_rounds:
        t0 = time.perf_counter()
        chunk = min(rounds_per_chunk, max_rounds - done_rounds)
        result = run_bp(pgm, scheduler, jax.random.fold_in(rng, done_rounds),
                        eps=eps, max_rounds=chunk, damping=0.0,
                        _init_logm=logm, _init_state=sstate)
        jax.block_until_ready(result.logm)
        if monitor is not None:
            monitor.record(time.perf_counter() - t0)
        logm, sstate = result.logm, result.sched_state
        done_rounds += int(result.rounds)
        if ckpt_dir is not None:
            save_pytree(ckpt_dir, done_rounds,
                        {"logm": logm, "sstate": sstate},
                        extra={"rounds": done_rounds})
        if bool(result.converged) or int(result.rounds) == 0:
            break
    return result
