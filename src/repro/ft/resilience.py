"""Fault tolerance & straggler mitigation.

Three mechanisms, matched to how BP and LM training actually fail at pod
scale:

1. **ElasticMesh** -- a mesh factory that re-lowers on device-count change.
   Checkpoints are mesh-agnostic (repro.checkpoint stores host arrays), so
   a job that loses a pod restarts on the surviving devices: reload the
   last step, rebuild the mesh from whatever ``jax.devices()`` now reports,
   re-lower. The dry-run exercises 256- and 512-chip meshes from the same
   code path, which is exactly this contract.

2. **StragglerMonitor** -- per-round wall-time EWMA with an outlier budget.
   At the driver level a round that exceeds ``budget_factor`` x EWMA marks
   a straggler event; the driver's response is workload-specific (BP:
   continue -- stale messages are *correct* under asynchronous BP semantics,
   the paper's own argument; training: flag the step for the health log and
   optionally skip the optimizer commit).

3. **run_bp_resilient** -- chunked BP execution on ``BPEngine.step``:
   instead of one unbounded ``while_loop``, run ``rounds_per_chunk`` at a
   time, checkpoint the full ``BPState`` (messages, scheduler state, RNG
   stream, counters) between chunks, and resume from the last chunk on
   crash. Because ``step`` carries the whole trajectory, the chunked run is
   *bit-identical* to the monolithic one, and a crash-restart loses at most
   one chunk of progress.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore_pytree, save_pytree
from repro.core.engine import BPConfig, BPEngine, BPState
from repro.core.graph import PGM


class ElasticMesh:
    """Rebuilds the (data, model)-style mesh from live devices."""

    def __init__(self, model_parallel: int = 1, axis_names=("data", "model")):
        self.model_parallel = model_parallel
        self.axis_names = axis_names
        self._n = 0

    def current(self):
        devs = jax.devices()
        n = len(devs)
        mp = min(self.model_parallel, n)
        while n % mp:
            mp -= 1
        self._n = n
        return jax.make_mesh((n // mp, mp), self.axis_names, devices=devs)

    def changed(self) -> bool:
        return len(jax.devices()) != self._n


@dataclasses.dataclass
class StragglerMonitor:
    budget_factor: float = 3.0
    alpha: float = 0.2
    ewma: float = 0.0
    events: int = 0
    rounds: int = 0

    def record(self, wall_s: float) -> bool:
        """Returns True if this round was a straggler."""
        self.rounds += 1
        if self.ewma == 0.0:
            self.ewma = wall_s
            return False
        straggler = wall_s > self.budget_factor * self.ewma
        if straggler:
            self.events += 1
        else:  # don't poison the EWMA with outliers
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * wall_s
        return straggler


def _state_payload(state: BPState) -> dict:
    """Checkpointable view of a ``BPState`` (typed RNG keys -> raw data;
    the graph itself is not persisted -- the caller re-supplies it)."""
    return {"logm": state.logm, "sstate": state.sched_state,
            "rng": jax.random.key_data(state.rng), "rounds": state.rounds,
            "done": state.done, "updates": state.updates,
            "hist": state.unconverged_history,
            "max_residual": state.max_residual}


def _restore_state(state: BPState, payload: dict) -> BPState:
    return dataclasses.replace(
        state, logm=payload["logm"], sched_state=payload["sstate"],
        rng=jax.random.wrap_key_data(jnp.asarray(payload["rng"])),
        rounds=jnp.asarray(payload["rounds"]),
        done=jnp.asarray(payload["done"]),
        updates=jnp.asarray(payload["updates"]),
        unconverged_history=jnp.asarray(payload["hist"]),
        max_residual=jnp.asarray(payload["max_residual"]))


def run_bp_resilient(pgm: PGM, scheduler, rng: jax.Array, *,
                     eps: float = 1e-3, max_rounds: int = 4000,
                     rounds_per_chunk: int = 200,
                     ckpt_dir: Optional[str] = None,
                     monitor: Optional[StragglerMonitor] = None):
    """Chunked, checkpointed BP on the engine's resumable ``step`` API.

    Returns the same ``BPResult`` as a monolithic run (``rounds`` counts
    only rounds executed by *this* call, so a crash-resume of a finished
    run reports 0). Resumes from ``ckpt_dir`` if it holds a newer chunk.
    Unlike the pre-engine implementation, the chunked trajectory is
    bit-identical to the monolithic one: ``BPState`` carries the RNG stream
    across chunk boundaries instead of re-seeding per chunk.
    """
    engine = BPEngine(BPConfig(scheduler=scheduler, eps=eps,
                               max_rounds=max_rounds,
                               chunk_rounds=rounds_per_chunk))
    state = engine.init(pgm, rng)
    base_rounds = 0
    if ckpt_dir is not None and (step := latest_step(ckpt_dir)) is not None:
        shape_of = lambda t: jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
        try:
            payload, extra = restore_pytree(ckpt_dir, step,
                                            shape_of(_state_payload(state)))
            state = _restore_state(state, payload)
        except KeyError:
            # Legacy pre-engine checkpoint: only {logm, sstate} were saved.
            # Resume the messages/scheduler state; counters come from the
            # manifest and the RNG stream restarts (the old per-chunk
            # re-seeding semantics) -- strictly better than crashing the
            # crash-recovery path on a format change.
            legacy, extra = restore_pytree(
                ckpt_dir, step,
                shape_of({"logm": state.logm, "sstate": state.sched_state}))
            state = dataclasses.replace(
                state, logm=jnp.asarray(legacy["logm"]),
                sched_state=jax.tree.map(jnp.asarray, legacy["sstate"]),
                rounds=jnp.int32(min(int(extra["rounds"]), max_rounds)))
        base_rounds = int(state.rounds)
    while not engine.finished(state):
        t0 = time.perf_counter()
        state = engine.step(state)
        jax.block_until_ready(state.logm)
        if monitor is not None:
            monitor.record(time.perf_counter() - t0)
        if ckpt_dir is not None:
            save_pytree(ckpt_dir, int(state.rounds), _state_payload(state),
                        extra={"rounds": int(state.rounds)})
    result = engine.result(state)
    return dataclasses.replace(
        result, rounds=result.rounds - jnp.int32(base_rounds))
