"""Model assembly: init / train-forward / prefill / decode for every family.

Layer stacks are ``lax.scan`` over parameters stacked on a leading L axis
(bounded HLO at 512 devices); deepseek's 3 dense lead-in layers form a
second, separate stack. Each block is wrapped in ``jax.checkpoint`` for the
training pass (per-layer remat, the production default at these sizes).

Batch dict contract (all optional keys per family):
  tokens   (B, S)  int32        text tokens (decoder tokens for enc-dec)
  labels   (B, S)  int32        next-token labels, -1 = masked
  frontend_embeds (B, T, d)     vlm: patch embeddings (prepended);
                                audio: encoder frame embeddings
Decode batch:  tokens (B, 1), pos () int32, plus the cache pytree.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import blocks as B
from repro.models.layers import attention as A
from repro.models.layers.basic import (dense_init, embed, init_embedding,
                                       rms_norm, unembed)

Params = Dict[str, Any]


def sinusoidal_positions(s: int, d: int) -> np.ndarray:
    pos = np.arange(s)[:, None]
    dim = np.arange(0, d, 2)[None, :] / d
    ang = pos / (10000.0 ** dim)
    out = np.zeros((s, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return out


def _xent(logits: jax.Array, labels: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Masked mean cross-entropy; labels -1 are ignored. logits f32."""
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(mask, logz - gold, 0.0)
    denom = jnp.maximum(mask.sum(), 1)
    return nll.sum() / denom, denom


class Model:
    """Family-polymorphic functional model bound to an ArchConfig."""

    def __init__(self, cfg: ArchConfig, act_spec=None):
        self.cfg = cfg
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        # Optional PartitionSpec pinned onto the residual stream after the
        # embedding and after every block. Under FSDP this is what forces
        # GSPMD to all-gather WEIGHTS (302 MB/layer) instead of resharding
        # ACTIVATIONS (51 GB/layer) -- see EXPERIMENTS.md SSPerf iter 4.
        self.act_spec = act_spec

    def _constrain(self, x):
        if self.act_spec is not None:
            return jax.lax.with_sharding_constraint(x, self.act_spec)
        return x

    # ------------------------------------------------------------- init --

    def _layer_kinds(self) -> Tuple[str, int, str, int]:
        """(lead_kind, lead_n, main_kind, main_n)."""
        cfg = self.cfg
        if cfg.ssm:
            return ("ssm", 0, "ssm", cfg.n_layers)
        if cfg.hybrid:
            return ("hybrid", 0, "hybrid", cfg.n_layers)
        if cfg.n_experts > 0:
            return ("dense", cfg.n_dense_layers, "moe",
                    cfg.n_layers - cfg.n_dense_layers)
        return ("dense", 0, "dense", cfg.n_layers)

    def init_params(self, key: jax.Array) -> Params:
        cfg = self.cfg
        k_embed, k_lead, k_main, k_head, k_enc, k_mtp = \
            jax.random.split(key, 6)
        p: Params = {"embed": init_embedding(k_embed, cfg.padded_vocab,
                                             cfg.d_model),
                     "final_norm": jnp.ones((cfg.d_model,), jnp.float32)}
        lead_kind, lead_n, main_kind, main_n = self._layer_kinds()
        def stack(key, n, init_fn):
            return jax.vmap(init_fn)(jax.random.split(key, n))
        if cfg.enc_dec:
            p["enc_blocks"] = stack(k_enc, cfg.n_enc_layers,
                                    lambda k: B.init_enc_block(k, cfg))
            p["enc_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
            p["blocks"] = stack(k_main, cfg.n_layers,
                                lambda k: B.init_xdec_block(k, cfg))
        else:
            if lead_n:
                p["lead_blocks"] = stack(
                    k_lead, lead_n, lambda k: B.init_block(k, cfg, lead_kind))
            p["blocks"] = stack(
                k_main, main_n, lambda k: B.init_block(k, cfg, main_kind))
        if not cfg.tie_embeddings:
            p["lm_head"] = {"table": dense_init(
                k_head, (cfg.padded_vocab, cfg.d_model))}
        if cfg.mtp:
            k1, k2 = jax.random.split(k_mtp)
            p["mtp"] = {"proj": dense_init(k1, (2 * cfg.d_model, cfg.d_model)),
                        "block": B.init_block(k2, cfg, "dense"),
                        "norm": jnp.ones((cfg.d_model,), jnp.float32)}
        return p

    def param_specs(self) -> Params:
        """ShapeDtypeStruct pytree of all parameters (no allocation)."""
        return jax.eval_shape(self.init_params, jax.random.key(0))

    # ------------------------------------------------------- embeddings --

    def _embed_inputs(self, params: Params, batch: Dict[str, jax.Array],
                      pos_offset: int = 0):
        """Returns (x (B,S,d), positions (B,S), labels-or-None)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = embed(params["embed"], tokens, self.dtype)
        labels = batch.get("labels")
        if cfg.frontend == "vision" and "frontend_embeds" in batch:
            fe = batch["frontend_embeds"].astype(self.dtype)
            x = jnp.concatenate([fe, x], axis=1)
            if labels is not None:
                pad = jnp.full(fe.shape[:2], -1, labels.dtype)
                labels = jnp.concatenate([pad, labels], axis=1)
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(
            jnp.arange(pos_offset, pos_offset + s, dtype=jnp.int32), (b, s))
        if cfg.rope_theta == 0.0:  # absolute sinusoidal (whisper)
            x = x + jnp.asarray(sinusoidal_positions(s, cfg.d_model),
                                self.dtype)[None]
        return x, positions, labels

    def _unembed(self, params: Params, x: jax.Array) -> jax.Array:
        head = params["embed"] if self.cfg.tie_embeddings \
            else params["lm_head"]
        return unembed(head, x)

    # ----------------------------------------------------------- encode --

    def _encode(self, params: Params, frames: jax.Array) -> jax.Array:
        """Whisper encoder over stub frame embeddings (B, S_enc, d)."""
        cfg = self.cfg
        x = frames.astype(self.dtype)
        s = x.shape[1]
        x = x + jnp.asarray(sinusoidal_positions(s, cfg.d_model),
                            self.dtype)[None]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32),
                                     (x.shape[0], s))

        def body(h, p_l):
            return B.enc_block_forward(p_l, h, positions, cfg), None

        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return rms_norm(params["enc_norm"], x)

    # ------------------------------------------------------------ train --

    def forward_train(self, params: Params, batch: Dict[str, jax.Array],
                      *, remat: bool = True):
        """Returns (loss, metrics dict)."""
        cfg = self.cfg
        if cfg.enc_dec:
            return self._forward_train_encdec(params, batch, remat=remat)
        x, positions, labels = self._embed_inputs(params, batch)
        lead_kind, lead_n, main_kind, main_n = self._layer_kinds()

        def make_body(kind):
            def body(carry, p_l):
                x, lb, zl = carry
                fn = functools.partial(B.block_forward, cfg=cfg, kind=kind)
                if remat:
                    fn = jax.checkpoint(fn)
                x, _, (l1, l2) = fn(p_l, x, positions)
                x = self._constrain(x)
                return (x, lb + l1, zl + l2), None
            return body

        carry = (self._constrain(x), jnp.float32(0.0), jnp.float32(0.0))
        if lead_n:
            carry, _ = jax.lax.scan(make_body(lead_kind), carry,
                                    params["lead_blocks"])
        carry, _ = jax.lax.scan(make_body(main_kind), carry,
                                params["blocks"])
        x, lb_loss, z_loss = carry
        x = rms_norm(params["final_norm"], x)
        logits = self._unembed(params, x)
        loss, n_tok = _xent(logits, labels)
        metrics = {"xent": loss, "n_tokens": n_tok}
        total = loss
        if cfg.n_experts:
            n_moe = main_n
            metrics["lb_loss"] = lb_loss / n_moe
            metrics["z_loss"] = z_loss / n_moe
            total = total + 0.01 * metrics["lb_loss"] \
                + 1e-3 * metrics["z_loss"]
        if cfg.mtp:
            mtp_loss = self._mtp_loss(params, x, batch, positions)
            metrics["mtp_loss"] = mtp_loss
            total = total + 0.3 * mtp_loss
        metrics["loss"] = total
        return total, metrics

    def _mtp_loss(self, params, h, batch, positions):
        """DeepSeek-V3 multi-token prediction (depth 1): predict t+2 from
        [h_t ; emb(tok_{t+1})]."""
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        emb_next = embed(params["embed"], jnp.roll(tokens, -1, axis=1),
                         self.dtype)
        z = jnp.concatenate([h.astype(self.dtype), emb_next], axis=-1)
        z = z @ params["mtp"]["proj"].astype(self.dtype)
        z, _, _ = B.block_forward(params["mtp"]["block"], z, positions,
                                  cfg=cfg, kind="dense")
        z = rms_norm(params["mtp"]["norm"], z)
        logits = self._unembed(params, z)
        mtp_labels = jnp.roll(labels, -1, axis=1).at[:, -2:].set(-1)
        loss, _ = _xent(logits, mtp_labels)
        return loss

    def _forward_train_encdec(self, params, batch, *, remat: bool = True):
        cfg = self.cfg
        enc_out = self._encode(params, batch["frontend_embeds"])
        x, positions, labels = self._embed_inputs(params, batch)

        def body(x, p_l):
            def fn(p_l, x):
                ek, ev = A.cross_kv(p_l["xattn"], enc_out,
                                    n_heads=cfg.n_heads,
                                    head_dim=cfg.resolved_head_dim)
                out, _ = B.xdec_block_forward(p_l, x, positions, ek, ev, cfg)
                return out
            if remat:
                fn = jax.checkpoint(fn)
            return fn(p_l, x), None

        x, _ = jax.lax.scan(body, x, params["blocks"])
        x = rms_norm(params["final_norm"], x)
        logits = self._unembed(params, x)
        loss, n_tok = _xent(logits, labels)
        return loss, {"xent": loss, "loss": loss, "n_tokens": n_tok}

    # ---------------------------------------------------------- prefill --

    def prefill(self, params: Params, batch: Dict[str, jax.Array]):
        """Full-prompt forward; returns (last-position logits, cache)."""
        cfg = self.cfg
        if cfg.enc_dec:
            return self._prefill_encdec(params, batch)
        x, positions, _ = self._embed_inputs(params, batch)
        lead_kind, lead_n, main_kind, main_n = self._layer_kinds()

        def make_body(kind):
            def body(x, p_l):
                x, cache, _ = B.block_forward(p_l, x, positions, cfg=cfg,
                                              kind=kind)
                return x, cache
            return body

        caches = {}
        if lead_n:
            x, caches["lead"] = jax.lax.scan(make_body(lead_kind), x,
                                             params["lead_blocks"])
        x, caches["main"] = jax.lax.scan(make_body(main_kind), x,
                                         params["blocks"])
        x = rms_norm(params["final_norm"], x)
        logits = self._unembed(params, x[:, -1:])
        return logits[:, 0], caches

    def _prefill_encdec(self, params, batch):
        cfg = self.cfg
        enc_out = self._encode(params, batch["frontend_embeds"])
        x, positions, _ = self._embed_inputs(params, batch)

        def body(x, p_l):
            ek, ev = A.cross_kv(p_l["xattn"], enc_out, n_heads=cfg.n_heads,
                                head_dim=cfg.resolved_head_dim)
            out, cache = B.xdec_block_forward(p_l, x, positions, ek, ev, cfg)
            cache = dict(cache, cross_k=ek, cross_v=ev)
            return out, cache

        x, caches = jax.lax.scan(body, x, params["blocks"])
        x = rms_norm(params["final_norm"], x)
        logits = self._unembed(params, x[:, -1:])
        return logits[:, 0], {"main": caches}

    # ----------------------------------------------------------- decode --

    def decode_step(self, params: Params, cache, tokens: jax.Array,
                    pos: jax.Array):
        """One new token. tokens (B, 1); cache as returned by
        ``init_cache_specs``/``prefill`` (padded to the serve length).
        Returns (logits (B, vocab), new cache)."""
        cfg = self.cfg
        x = embed(params["embed"], tokens, self.dtype)
        if cfg.rope_theta == 0.0:
            # absolute sinusoidal at (traced) position `pos` (whisper)
            dim = jnp.arange(0, cfg.d_model, 2, dtype=jnp.float32) \
                / cfg.d_model
            ang = pos.astype(jnp.float32) / (10000.0 ** dim)
            pe = jnp.zeros((cfg.d_model,), jnp.float32)
            pe = pe.at[0::2].set(jnp.sin(ang)).at[1::2].set(jnp.cos(ang))
            x = x + pe.astype(self.dtype)[None, None, :]
        lead_kind, lead_n, main_kind, main_n = self._layer_kinds()
        new_cache = {}

        if cfg.enc_dec:
            def body(x1, inp):
                p_l, c_l = inp
                out, c_new = B.xdec_block_decode(
                    p_l, x1, c_l, c_l["cross_k"], c_l["cross_v"], pos, cfg)
                c_new = dict(c_new, cross_k=c_l["cross_k"],
                             cross_v=c_l["cross_v"])
                return out, c_new
            x, new_cache["main"] = jax.lax.scan(
                body, x, (params["blocks"], cache["main"]))
        else:
            def make_body(kind):
                def body(x1, inp):
                    p_l, c_l = inp
                    return B.block_decode(p_l, x1, c_l, pos, cfg, kind)
                return body
            if lead_n:
                x, new_cache["lead"] = jax.lax.scan(
                    make_body(lead_kind), x,
                    (params["lead_blocks"], cache["lead"]))
            x, new_cache["main"] = jax.lax.scan(
                make_body(main_kind), x, (params["blocks"], cache["main"]))

        x = rms_norm(params["final_norm"], x)
        logits = self._unembed(params, x)
        return logits[:, 0], new_cache

    # ------------------------------------------------------ cache specs --

    def _block_cache_spec(self, kind: str, b: int, s: int):
        cfg = self.cfg
        dt = self.dtype
        kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        if kind == "ssm":
            h = cfg.d_inner // cfg.ssm_head_p
            return {
                "ssm": jax.ShapeDtypeStruct(
                    (b, h, cfg.ssm_head_p, cfg.ssm_state), jnp.float32),
                "conv": jax.ShapeDtypeStruct(
                    (b, 3, cfg.d_inner + 2 * cfg.ssm_state), dt)}
        if kind == "hybrid":
            w = cfg.sliding_window
            h = cfg.d_inner // cfg.ssm_head_p
            return {
                "k": jax.ShapeDtypeStruct((b, w, kvh, hd), dt),
                "v": jax.ShapeDtypeStruct((b, w, kvh, hd), dt),
                "pos": jax.ShapeDtypeStruct((w,), jnp.int32),
                "ssm": jax.ShapeDtypeStruct(
                    (b, h, cfg.ssm_head_p, cfg.ssm_state), jnp.float32),
                "conv": jax.ShapeDtypeStruct(
                    (b, 3, cfg.d_inner + 2 * cfg.ssm_state), dt)}
        if cfg.mla:
            return {"c_kv": jax.ShapeDtypeStruct((b, s, cfg.kv_lora_rank), dt),
                    "k_rope": jax.ShapeDtypeStruct((b, s, cfg.qk_rope_dim),
                                                   dt)}
        spec = {"k": jax.ShapeDtypeStruct((b, s, kvh, hd), dt),
                "v": jax.ShapeDtypeStruct((b, s, kvh, hd), dt)}
        if cfg.enc_dec:
            spec["cross_k"] = jax.ShapeDtypeStruct((b, s, cfg.n_heads, hd), dt)
            spec["cross_v"] = jax.ShapeDtypeStruct((b, s, cfg.n_heads, hd), dt)
        return spec

    def init_cache_specs(self, batch_size: int, seq_len: int):
        """ShapeDtypeStruct pytree for the decode cache at serve length."""
        lead_kind, lead_n, main_kind, main_n = self._layer_kinds()
        def stack(spec_tree, n):
            return jax.tree.map(
                lambda sd: jax.ShapeDtypeStruct((n,) + sd.shape, sd.dtype),
                spec_tree)
        out = {"main": stack(self._block_cache_spec(main_kind, batch_size,
                                                    seq_len), main_n)}
        if lead_n:
            out["lead"] = stack(self._block_cache_spec(lead_kind, batch_size,
                                                       seq_len), lead_n)
        return out

    def init_cache(self, batch_size: int, seq_len: int):
        """Zero-initialized cache (hybrid 'pos' slots = -1)."""
        def mk(sd: jax.ShapeDtypeStruct):
            return jnp.zeros(sd.shape, sd.dtype)
        cache = jax.tree.map(mk, self.init_cache_specs(batch_size, seq_len))
        if self.cfg.hybrid:
            cache["main"]["pos"] = jnp.full_like(cache["main"]["pos"], -1)
        return cache


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
