"""Multi-head Latent Attention (DeepSeek-V2/V3, arXiv:2412.19437).

Projections:
  q:  x -> c_q (q_lora_rank) -> per-head [q_nope (nope_d) ; q_rope (rope_d)]
  kv: x -> c_kv (kv_lora_rank)  and  x -> k_rope (rope_d, shared per head)
      c_kv -> per-head k_nope (nope_d), v (v_d)

Decode caches ONLY (c_kv, k_rope) -- the compressed latent -- and uses the
*weight absorption* identity so per-step cost is O(S * (kv_lora + rope_d))
per head instead of re-expanding the whole cache:

  score = q_nope . (c W_uk) + q_rope . k_rope
        = (q_nope W_uk^T) . c + q_rope . k_rope
  out_h = (attn . c) W_uv
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.basic import apply_rope, dense_init, rms_norm

NEG = -1.0e30


def init_mla(key, d_model: int, n_heads: int, *, q_lora: int, kv_lora: int,
             rope_d: int, nope_d: int, v_d: int):
    ks = jax.random.split(key, 8)
    return {
        "w_dq": dense_init(ks[0], (d_model, q_lora)),
        "q_norm": jnp.ones((q_lora,), jnp.float32),
        "w_uq": dense_init(ks[1], (q_lora, n_heads * (nope_d + rope_d))),
        "w_dkv": dense_init(ks[2], (d_model, kv_lora)),
        "kv_norm": jnp.ones((kv_lora,), jnp.float32),
        "w_kr": dense_init(ks[3], (d_model, rope_d)),
        "w_uk": dense_init(ks[4], (kv_lora, n_heads * nope_d)),
        "w_uv": dense_init(ks[5], (kv_lora, n_heads * v_d)),
        "wo": dense_init(ks[6], (n_heads * v_d, d_model)),
    }


def _project_q(p, x, n_heads, nope_d, rope_d, positions):
    b, s, _ = x.shape
    cq = rms_norm(p["q_norm"], x @ p["w_dq"].astype(x.dtype))
    q = (cq @ p["w_uq"].astype(x.dtype)).reshape(b, s, n_heads,
                                                 nope_d + rope_d)
    q_nope, q_rope = q[..., :nope_d], q[..., nope_d:]
    q_rope = apply_rope(q_rope, positions, 1e4)
    return q_nope, q_rope


def mla_forward(p, x, positions, *, n_heads, q_lora, kv_lora, rope_d, nope_d,
                v_d, q_block=512):
    """Full-sequence causal MLA. Returns (out, (c_kv, k_rope)) for caching."""
    b, s, _ = x.shape
    q_nope, q_rope = _project_q(p, x, n_heads, nope_d, rope_d, positions)
    c_kv = rms_norm(p["kv_norm"], x @ p["w_dkv"].astype(x.dtype))  # (B,S,ckv)
    k_rope = apply_rope((x @ p["w_kr"].astype(x.dtype))[:, :, None, :],
                        positions, 1e4)[:, :, 0]                    # (B,S,rd)
    k_nope = (c_kv @ p["w_uk"].astype(x.dtype)).reshape(b, s, n_heads, nope_d)
    v = (c_kv @ p["w_uv"].astype(x.dtype)).reshape(b, s, n_heads, v_d)
    scale = 1.0 / jnp.sqrt(jnp.float32(nope_d + rope_d))
    kpos = jnp.broadcast_to(positions, (b, s)) if positions.ndim == 1 \
        else positions

    def attend(qn, qr, qpos):
        sc = (jnp.einsum("bqhd,bkhd->bhqk", qn.astype(jnp.float32),
                         k_nope.astype(jnp.float32))
              + jnp.einsum("bqhd,bkd->bhqk", qr.astype(jnp.float32),
                           k_rope.astype(jnp.float32))) * scale
        mask = qpos[:, None, :, None] >= kpos[:, None, None, :]
        sc = jnp.where(mask, sc, NEG)
        w = jax.nn.softmax(sc, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)

    if s <= q_block:
        out = attend(q_nope, q_rope, kpos)
    else:
        assert s % q_block == 0
        nb = s // q_block
        def body(_, inp):
            qn, qr, qp = inp
            return None, attend(qn, qr, qp)
        _, ob = jax.lax.scan(body, None, (
            jnp.moveaxis(q_nope.reshape(b, nb, q_block, n_heads, nope_d), 1, 0),
            jnp.moveaxis(q_rope.reshape(b, nb, q_block, n_heads, rope_d), 1, 0),
            jnp.moveaxis(kpos.reshape(b, nb, q_block), 1, 0)))
        out = jnp.moveaxis(ob, 0, 1).reshape(b, s, n_heads, v_d)
    out = out.reshape(b, s, n_heads * v_d)
    return out @ p["wo"].astype(x.dtype), (c_kv, k_rope)


def mla_decode(p, x1, cache_c, cache_kr, pos, *, n_heads, q_lora, kv_lora,
               rope_d, nope_d, v_d):
    """Absorbed one-token decode. cache_c: (B,S,kv_lora); cache_kr: (B,S,rd)."""
    b = x1.shape[0]
    s_cache = cache_c.shape[1]
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q_nope, q_rope = _project_q(p, x1, n_heads, nope_d, rope_d, positions)
    c_new = rms_norm(p["kv_norm"], x1 @ p["w_dkv"].astype(x1.dtype))
    kr_new = apply_rope((x1 @ p["w_kr"].astype(x1.dtype))[:, :, None, :],
                        positions, 1e4)[:, :, 0]
    cache_c = jax.lax.dynamic_update_slice_in_dim(
        cache_c, c_new.astype(cache_c.dtype), pos, axis=1)
    cache_kr = jax.lax.dynamic_update_slice_in_dim(
        cache_kr, kr_new.astype(cache_kr.dtype), pos, axis=1)
    # absorption: q_abs[h, ckv] = q_nope[h] @ W_uk[h]^T
    w_uk = p["w_uk"].astype(x1.dtype).reshape(kv_lora, n_heads, nope_d)
    q_abs = jnp.einsum("bqhd,chd->bqhc", q_nope, w_uk)        # (B,1,H,ckv)
    scale = 1.0 / jnp.sqrt(jnp.float32(nope_d + rope_d))
    sc = (jnp.einsum("bqhc,bkc->bhqk", q_abs.astype(jnp.float32),
                     cache_c.astype(jnp.float32))
          + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                       cache_kr.astype(jnp.float32))) * scale
    kpos = jnp.arange(s_cache)
    sc = jnp.where((kpos <= pos)[None, None, None, :], sc, NEG)
    w = jax.nn.softmax(sc, axis=-1)
    ctx = jnp.einsum("bhqk,bkc->bqhc", w, cache_c.astype(jnp.float32))
    w_uv = p["w_uv"].astype(jnp.float32).reshape(kv_lora, n_heads, v_d)
    out = jnp.einsum("bqhc,chd->bqhd", ctx, w_uv).astype(x1.dtype)
    out = out.reshape(b, 1, n_heads * v_d)
    return out @ p["wo"].astype(x1.dtype), cache_c, cache_kr
