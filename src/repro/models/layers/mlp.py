"""Gated MLPs (SwiGLU / GeGLU) and the plain enc-dec FFN."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.basic import act_fn, dense_init


def init_mlp(key, d_model: int, d_ff: int, gated: bool = True):
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], (d_model, d_ff)),
         "w_out": dense_init(ks[1], (d_ff, d_model))}
    if gated:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff))
    return p


def mlp(p, x, act: str = "silu"):
    h = x @ p["w_in"].astype(x.dtype)
    if "w_gate" in p:
        h = act_fn(act)(x @ p["w_gate"].astype(x.dtype)) * h
    else:
        h = act_fn(act)(h)
    return h @ p["w_out"].astype(x.dtype)
