"""Mamba-2 (SSD, state-space duality) layer -- arXiv:2405.21060.

Chunked SSD forward (training/prefill): the sequence is split into chunks of
``chunk`` tokens; within a chunk the quadratic "attention-like" form runs on
the MXU, across chunks a tiny ``lax.scan`` carries the (H, P, N) state. This
is the TPU-native formulation: all heavy ops are batched matmuls, the scan
carry is O(H*P*N) regardless of sequence length -- which is exactly why the
``long_500k`` shape is runnable for SSM/hybrid archs and skipped for pure
attention.

Decode: O(1) per token -- h = h * exp(A dt) + dt * (B outer x); y = C . h.

Layout: x is (B, S, d_inner) with d_inner = n_heads * head_p. Sharding puts
n_heads on "model" when divisible (resolver's job), state N stays local.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.basic import dense_init, rms_norm

CONV_K = 4


def init_ssm(key, d_model: int, d_inner: int, d_state: int, head_p: int = 64):
    n_heads = d_inner // head_p
    ks = jax.random.split(key, 8)
    return {
        # fused input projection: [z, x, B, C, dt]
        "w_in": dense_init(ks[0], (d_model,
                                   2 * d_inner + 2 * d_state + n_heads)),
        "conv_w": dense_init(ks[1], (CONV_K, d_inner + 2 * d_state),
                             scale=0.5),
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm_w": jnp.ones((d_inner,), jnp.float32),
        "w_out": dense_init(ks[2], (d_inner, d_model)),
    }


def _split_proj(p, x, d_inner, d_state, n_heads):
    zxbcdt = x @ p["w_in"].astype(x.dtype)
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * d_state],
                           axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_state=None):
    """Depthwise causal conv, kernel CONV_K. xbc: (B, S, C).
    conv_state: (B, CONV_K-1, C) history for decode; returns (out, new_state)."""
    w = conv_w.astype(xbc.dtype)                       # (K, C)
    if conv_state is None:
        pad = jnp.zeros_like(xbc[:, :CONV_K - 1])
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)           # (B, S+K-1, C)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i] for i in range(CONV_K))
    new_state = xp[:, -(CONV_K - 1):]
    return jax.nn.silu(out), new_state


def ssd_chunked(x, dt, A, B, C, *, chunk: int):
    """SSD scan. x: (b,S,H,P); dt: (b,S,H); A: (H,); B,C: (b,S,N).
    Returns (y (b,S,H,P), final_state (b,H,P,N)). S % chunk == 0."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    nc = s // chunk
    f32 = jnp.float32
    xc = x.reshape(b, nc, chunk, h, p).astype(f32)
    dtc = dt.reshape(b, nc, chunk, h).astype(f32)
    Bc = B.reshape(b, nc, chunk, n).astype(f32)
    Cc = C.reshape(b, nc, chunk, n).astype(f32)
    dA = dtc * A.astype(f32)[None, None, None, :]          # (b,nc,L,h) <= 0
    cum = jnp.cumsum(dA, axis=2)                           # within-chunk
    seg_end = cum[:, :, -1]                                # (b,nc,h)

    # intra-chunk (quadratic, masked decay):  L[i,j] = exp(cum_i - cum_j) i>=j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (b,nc,Lq,Lk,h)
    iq = jnp.arange(chunk)
    causal = (iq[:, None] >= iq[None, :])[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)             # (b,nc,Lq,Lk)
    y_intra = jnp.einsum("bcqk,bcqkh,bckh,bckhp->bcqhp",
                         cb, L, dtc, xc)

    # chunk states: S_c = sum_k exp(segend - cum_k) dt_k B_k (x) x_k
    decay_out = jnp.exp(seg_end[:, :, None, :] - cum)      # (b,nc,L,h)
    states = jnp.einsum("bckn,bckh,bckh,bckhp->bchpn",
                        Bc, decay_out, dtc, xc)            # (b,nc,h,p,n)

    # inter-chunk recurrence over nc (the only sequential part)
    def step(hprev, inp):
        st, se = inp                                       # (b,h,p,n),(b,h)
        hnew = hprev * jnp.exp(se)[:, :, None, None] + st
        return hnew, hprev

    h0 = jnp.zeros((b, h, p, n), f32)
    hlast, hprevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(seg_end, 1, 0)))
    hprevs = jnp.moveaxis(hprevs, 0, 1)                    # (b,nc,h,p,n)

    # inter-chunk output: y_j += exp(cum_j) C_j . H_{c-1}
    decay_in = jnp.exp(cum)                                # (b,nc,L,h)
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, decay_in, hprevs)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(x.dtype), hlast


def ssm_forward(p, x, *, d_inner: int, d_state: int, head_p: int = 64,
                chunk: int = 256):
    """Full-sequence Mamba-2 block body. x: (B, S, d_model).
    Returns (out, (final_state, conv_state))."""
    b, s, _ = x.shape
    n_heads = d_inner // head_p
    z, xbc, dt = _split_proj(p, x, d_inner, d_state, n_heads)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"])
    xi, B, C = jnp.split(xbc, [d_inner, d_inner + d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    xh = xi.reshape(b, s, n_heads, head_p)
    pad = (-s) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    y, hlast = ssd_chunked(xh, dt, A, B, C, chunk=chunk)
    y = y[:, :s]
    y = y + p["D"].astype(y.dtype)[None, None, :, None] \
        * xi.reshape(b, s, n_heads, head_p)
    y = y.reshape(b, s, d_inner)
    y = rms_norm(p["norm_w"], y * jax.nn.silu(z))
    return y @ p["w_out"].astype(x.dtype), (hlast, conv_state)


def ssm_decode(p, x1, ssm_state, conv_state, *, d_inner: int, d_state: int,
               head_p: int = 64):
    """One-token decode. x1: (B,1,d_model); ssm_state: (B,H,P,N);
    conv_state: (B, CONV_K-1, d_inner+2N). Returns (out, new_ssm, new_conv)."""
    b = x1.shape[0]
    n_heads = d_inner // head_p
    z, xbc, dt = _split_proj(p, x1, d_inner, d_state, n_heads)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], conv_state)
    xi, B, C = jnp.split(xbc, [d_inner, d_inner + d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,1,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)[:, 0]                                    # (B,H)
    xh = xi.reshape(b, n_heads, head_p).astype(jnp.float32)
    Bf = B[:, 0].astype(jnp.float32)                              # (B,N)
    new_state = (ssm_state * dA[:, :, None, None]
                 + jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0], xh, Bf))
    y = jnp.einsum("bn,bhpn->bhp", C[:, 0].astype(jnp.float32), new_state)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(b, 1, d_inner).astype(x1.dtype)
    y = rms_norm(p["norm_w"], y * jax.nn.silu(z))
    return y @ p["w_out"].astype(x1.dtype), new_state, conv_state
