"""Norms, activations, RoPE, embeddings -- shared primitives.

All layer functions take (params_subtree, inputs, ...) and are shape-
polymorphic; initializers return {name: array} dicts. Weights are created in
float32 and cast per config dtype at the boundary (mixed-precision policy:
bf16 compute, f32 master weights handled by the optimizer)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(w: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(dt)


def layer_norm(w: jax.Array, b: jax.Array, x: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
            "relu": jax.nn.relu}[name]


def dense_init(key, shape, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale)


# ---------------------------------------------------------------- RoPE ----

def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d, theta), dtype=jnp.float32)
    angles = positions[..., None].astype(jnp.float32) * freqs      # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                            # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------- embeddings ---

def init_embedding(key, vocab: int, d_model: int):
    # 0.02 std (GPT-2 convention) keeps tied-unembedding logits sane at init.
    return {"table": dense_init(key, (vocab, d_model), scale=0.02)}


def embed(params, tokens: jax.Array, dtype) -> jax.Array:
    return params["table"].astype(dtype)[tokens]


def unembed(params, x: jax.Array) -> jax.Array:
    """Logits in f32 (loss stability)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      params["table"].astype(jnp.float32))
