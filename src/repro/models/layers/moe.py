"""Mixture-of-Experts with top-k routing and grouped-GEMM dispatch.

Dispatch strategy (TPU-native): tokens are replicated-to-(T*topk), sorted by
assigned expert, and run through ``jax.lax.ragged_dot`` grouped GEMMs -- the
XLA analogue of a grouped GEMM kernel; FLOPs are exactly the *active* FLOPs
(6 * N_active * D counts in the roofline use this). No capacity dropping:
group sizes are data-dependent but the GEMM is dense in total rows, so
shapes stay static.

Sharding: expert weights are sharded on the *d_ff* dimension over the
"model" axis (tensor-parallel experts). This avoids all-to-all dispatch
entirely -- every device holds a 1/TP slice of EVERY expert, tokens stay
put, and the only collective is the same psum as a dense TP MLP. Expert-
parallel (all_to_all) dispatch is the documented alternative; see
EXPERIMENTS.md SSPerf for the comparison on the MoE hillclimb cell.

Aux losses: standard load-balance loss (Switch-style) + router z-loss,
returned for the train step to weight.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.basic import act_fn, dense_init


def init_moe(key, d_model: int, n_experts: int, d_ff: int,
             n_shared: int = 0, shared_d_ff: int = 0):
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], (d_model, n_experts), scale=0.02),
        "w_in": dense_init(ks[1], (n_experts, d_model, d_ff)),
        "w_gate": dense_init(ks[2], (n_experts, d_model, d_ff)),
        "w_out": dense_init(ks[3], (n_experts, d_ff, d_model)),
    }
    if n_shared > 0:
        sf = shared_d_ff or d_ff
        p["shared_w_in"] = dense_init(ks[4], (d_model, n_shared * sf))
        p["shared_w_gate"] = dense_init(ks[5], (d_model, n_shared * sf))
        p["shared_w_out"] = dense_init(
            jax.random.fold_in(key, 7), (n_shared * sf, d_model))
    return p


_SHARD_MESH = {"mesh": None}


def set_shard_mesh(mesh) -> None:
    """Register the mesh used by dispatch='sharded' (launcher calls this
    before tracing; shard_map needs a concrete mesh object)."""
    _SHARD_MESH["mesh"] = mesh


def _route(p, xt, top_k):
    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)                        # (T, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return logits, probs, top_p, top_e


def _ragged_experts(p_w_in, p_w_gate, p_w_out, xt, top_p, top_e,
                    n_experts, top_k, act):
    """Sort-and-group grouped-GEMM dispatch over one token shard."""
    t, d = xt.shape
    flat_e = top_e.reshape(-1)                                       # (T*K,)
    order = jnp.argsort(flat_e)                                      # stable
    inv = jnp.argsort(order)
    rows = xt[jnp.repeat(jnp.arange(t), top_k)[order]]               # (T*K, d)
    group_sizes = jnp.bincount(flat_e, length=n_experts)
    h_in = jax.lax.ragged_dot(rows, p_w_in, group_sizes)
    h_gate = jax.lax.ragged_dot(rows, p_w_gate, group_sizes)
    h = act_fn(act)(h_gate) * h_in
    out_rows = jax.lax.ragged_dot(h, p_w_out, group_sizes)
    out_rows = out_rows[inv].reshape(t, top_k, d)
    return jnp.einsum("tkd,tk->td", out_rows, top_p.astype(xt.dtype))


def moe(p, x, *, n_experts: int, top_k: int, act: str = "silu",
        dispatch: str = "ragged", shard_axes=None):
    """x: (B, S, d). Returns (out, aux) with aux = (lb_loss, z_loss).

    dispatch:
      "ragged"  global sort-and-group grouped GEMM (baseline). Correct, but
                under pjit the global argsort/gather reshards the full token
                set every layer -- catastrophically collective-bound at pod
                scale (see EXPERIMENTS.md SSPerf, MoE cell).
      "dense"   compute ALL experts on all tokens, combine with routing
                weights. E/top_k x the active FLOPs but zero dispatch
                communication -- the right trade for few-expert models
                (granite: E=40, d_ff=512 -> 5x tiny GEMMs beat a global
                sort by ~50x on the collective term).
      "sharded" shard_map over ``shard_axes``: tokens stay device-local, the
                sort-and-group runs per shard (the paper-scale fix for
                many-expert models, deepseek E=256); expert weights arrive
                d_ff-sliced, one psum after the down-projection.
    """
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    logits, probs, top_p, top_e = _route(p, xt, top_k)

    if dispatch == "dense":
        w_full = jax.nn.one_hot(top_e, n_experts, dtype=x.dtype)     # (T,K,E)
        w_full = jnp.einsum("tke,tk->te", w_full, top_p.astype(x.dtype))
        h_in = jnp.einsum("td,edf->tef", xt, p["w_in"].astype(x.dtype))
        h_gate = jnp.einsum("td,edf->tef", xt, p["w_gate"].astype(x.dtype))
        h = act_fn(act)(h_gate) * h_in
        out = jnp.einsum("tef,efd,te->td", h, p["w_out"].astype(x.dtype),
                         w_full)
    elif dispatch == "sharded":
        from jax.sharding import PartitionSpec as P
        # shard_axes = (token_axes, ff_axis): tokens stay on their data
        # shard (local sort-and-group, NO global dispatch traffic), expert
        # weights arrive d_ff-sliced on the model axis; the only collective
        # is the standard TP psum of the (T_loc, d) down-projection output.
        if shard_axes is None:
            # derive from the registered mesh (set_shard_mesh): "model"
            # slices d_ff, every other nontrivial axis carries tokens
            am = _SHARD_MESH["mesh"]
            assert am is not None, \
                "moe dispatch='sharded' needs set_shard_mesh(mesh)"
            tok_axes = tuple(a for a in am.axis_names
                             if a != "model" and am.shape[a] > 1) or None
            ff_axis = "model"
        else:
            tok_axes, ff_axis = shard_axes
            am = _SHARD_MESH["mesh"]

        def local(xt_l, tp_l, te_l, w_in_l, w_gate_l, w_out_l):
            out_l = _ragged_experts(w_in_l, w_gate_l, w_out_l, xt_l, tp_l,
                                    te_l, n_experts, top_k, act)
            return jax.lax.psum(out_l, ff_axis)

        out = jax.shard_map(
            local, mesh=am,
            in_specs=(P(tok_axes, None), P(tok_axes, None),
                      P(tok_axes, None),
                      P(None, None, ff_axis), P(None, None, ff_axis),
                      P(None, ff_axis, None)),
            out_specs=P(tok_axes, None),
            check_vma=False,
        )(xt, top_p, top_e, p["w_in"].astype(x.dtype),
          p["w_gate"].astype(x.dtype), p["w_out"].astype(x.dtype))
    else:
        out = _ragged_experts(p["w_in"].astype(x.dtype),
                              p["w_gate"].astype(x.dtype),
                              p["w_out"].astype(x.dtype),
                              xt, top_p, top_e, n_experts, top_k, act)

    if "shared_w_in" in p:
        hs = (act_fn(act)(xt @ p["shared_w_gate"].astype(x.dtype))
              * (xt @ p["shared_w_in"].astype(x.dtype)))
        out = out + hs @ p["shared_w_out"].astype(x.dtype)

    # --- aux losses --------------------------------------------------------
    # load balance: E * sum_e f_e * P_e  (f = fraction routed, P = mean prob)
    f = jnp.bincount(top_e.reshape(-1),
                     length=n_experts).astype(jnp.float32) / (t * top_k)
    pbar = probs.mean(axis=0)
    lb_loss = n_experts * jnp.sum(f * pbar)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return out.reshape(b, s, d), (lb_loss, z_loss)
