"""Attention: GQA/MQA/MHA + RoPE + optional qk-norm + sliding window +
cross-attention, with three lowering modes:

  * ``attn_forward``  -- full-sequence causal (train / prefill). Queries are
    processed in blocks via ``lax.scan`` (flash-style O(S * blk) score
    memory, exact softmax over the full key axis per block) so 32k prefill
    fits HBM without materializing the S^2 score tensor.
  * ``attn_decode``   -- one new token against a (B, S, KVH, D) KV cache,
    written in place at ``pos`` (dynamic_update_slice lands on the owning
    shard under pjit).
  * ``cross_attn``    -- decoder-over-encoder (whisper), no mask, static KV.

Layout notes for sharding: projections keep heads fused as (S, H*D) until
after the matmul so the "model" axis shards the contraction output; the
(H, D) split happens immediately before the attention einsum, where H (or D,
resolver's choice) carries the sharding.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.basic import apply_rope, dense_init, rms_norm

NEG = -1.0e30


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, qk_norm: bool = False):
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d_model, n_heads * head_dim)),
        "wk": dense_init(ks[1], (d_model, n_kv_heads * head_dim)),
        "wv": dense_init(ks[2], (d_model, n_kv_heads * head_dim)),
        "wo": dense_init(ks[3], (n_heads * head_dim, d_model)),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((head_dim,), jnp.float32)
    return p


def _project_qkv(p, x, n_heads, n_kv_heads, head_dim, positions, rope_theta,
                 qk_norm):
    b, s, _ = x.shape
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, n_heads, head_dim)
    k = (x @ p["wk"].astype(x.dtype)).reshape(b, s, n_kv_heads, head_dim)
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, s, n_kv_heads, head_dim)
    if qk_norm:
        q = rms_norm(p["q_norm"], q)
        k = rms_norm(p["k_norm"], k)
    if rope_theta > 0:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


def _gqa_scores_block(qb, k, scale):
    """qb: (B, Sq, KVH, G, D); k: (B, Sk, KVH, D) -> (B, KVH, G, Sq, Sk)."""
    return jnp.einsum("bqhgd,bshd->bhgqs", qb.astype(jnp.float32),
                      k.astype(jnp.float32)) * scale


def _attend_block(qb, k, v, mask, scale):
    s = _gqa_scores_block(qb, k, scale)
    s = jnp.where(mask, s, NEG)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", w.astype(v.dtype), v)
    return out


def attn_forward(p, x, positions, *, n_heads, n_kv_heads, head_dim,
                 rope_theta=1e4, qk_norm=False, causal=True,
                 sliding_window=0, q_block=512):
    """Full-sequence attention; returns (out (B,S,d_model-ish), (k, v))."""
    b, s, _ = x.shape
    g = n_heads // n_kv_heads
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim, positions,
                           rope_theta, qk_norm)
    scale = 1.0 / jnp.sqrt(head_dim).astype(jnp.float32)
    qg = q.reshape(b, s, n_kv_heads, g, head_dim)
    kpos = positions  # (B, S) or (S,)
    kpos = jnp.broadcast_to(kpos, (b, s)) if kpos.ndim == 1 else kpos

    if s <= q_block:
        qpos = kpos
        mask = jnp.ones((b, 1, 1, s, s), bool)
        if causal:
            mask = mask & (qpos[:, None, None, :, None]
                           >= kpos[:, None, None, None, :])
        if sliding_window > 0:
            mask = mask & (qpos[:, None, None, :, None] - sliding_window
                           < kpos[:, None, None, None, :])
        out = _attend_block(qg, k, v, mask, scale)
    else:
        assert s % q_block == 0, (s, q_block)
        nblk = s // q_block
        qblocks = qg.reshape(b, nblk, q_block, n_kv_heads, g, head_dim)
        qpos_blocks = kpos.reshape(b, nblk, q_block)

        def body(_, inp):
            qb, qpos = inp                       # (B,blk,KVH,G,D), (B,blk)
            m = jnp.ones((b, 1, 1, q_block, s), bool)
            if causal:
                m = m & (qpos[:, None, None, :, None]
                         >= kpos[:, None, None, None, :])
            if sliding_window > 0:
                m = m & (qpos[:, None, None, :, None] - sliding_window
                         < kpos[:, None, None, None, :])
            return None, _attend_block(qb, k, v, m, scale)

        _, outb = jax.lax.scan(
            body, None,
            (jnp.moveaxis(qblocks, 1, 0), jnp.moveaxis(qpos_blocks, 1, 0)))
        out = jnp.moveaxis(outb, 0, 1).reshape(b, s, n_kv_heads, g, head_dim)

    out = out.reshape(b, s, n_heads * head_dim)
    return out @ p["wo"].astype(x.dtype), (k, v)


def attn_decode(p, x1, cache_k, cache_v, pos, *, n_heads, n_kv_heads,
                head_dim, rope_theta=1e4, qk_norm=False, sliding_window=0):
    """One-token decode. x1: (B, 1, d); cache: (B, S, KVH, D); pos: () int.

    Returns (out (B,1,d_model), new_cache_k, new_cache_v)."""
    b = x1.shape[0]
    s_cache = cache_k.shape[1]
    g = n_heads // n_kv_heads
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q, k, v = _project_qkv(p, x1, n_heads, n_kv_heads, head_dim, positions,
                           rope_theta, qk_norm)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), pos, axis=1)
    kpos = jnp.arange(s_cache, dtype=jnp.int32)
    valid = kpos <= pos
    if sliding_window > 0:
        valid = valid & (kpos > pos - sliding_window)
    mask = valid[None, None, None, None, :]
    qg = q.reshape(b, 1, n_kv_heads, g, head_dim)
    scale = 1.0 / jnp.sqrt(head_dim).astype(jnp.float32)
    out = _attend_block(qg, cache_k, cache_v, mask, scale)
    out = out.reshape(b, 1, n_heads * head_dim)
    return out @ p["wo"].astype(x1.dtype), cache_k, cache_v


def attn_decode_ring(p, x1, cache_k, cache_v, cache_pos, pos, *, n_heads,
                     n_kv_heads, head_dim, rope_theta=1e4, qk_norm=False,
                     sliding_window=0):
    """Sliding-window decode with a ring-buffer cache of width W.

    cache_k/v: (B, W, KVH, D) with RoPE already applied at write time;
    cache_pos: (W,) absolute positions (-1 = empty). The new token writes at
    slot ``pos % W`` so cache memory is O(W) however long the stream -- this
    is what makes ``long_500k`` decodable for the hybrid arch."""
    b = x1.shape[0]
    w = cache_k.shape[1]
    g = n_heads // n_kv_heads
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q, k, v = _project_qkv(p, x1, n_heads, n_kv_heads, head_dim, positions,
                           rope_theta, qk_norm)
    slot = jnp.mod(pos, w)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), slot, axis=1)
    cache_pos = jax.lax.dynamic_update_slice_in_dim(
        cache_pos, positions[0, :1], slot, axis=0)
    valid = (cache_pos >= 0) & (cache_pos <= pos)
    if sliding_window > 0:
        valid = valid & (cache_pos > pos - sliding_window)
    mask = valid[None, None, None, None, :]
    qg = q.reshape(b, 1, n_kv_heads, g, head_dim)
    scale = 1.0 / jnp.sqrt(head_dim).astype(jnp.float32)
    out = _attend_block(qg, cache_k, cache_v, mask, scale)
    out = out.reshape(b, 1, n_heads * head_dim)
    return out @ p["wo"].astype(x1.dtype), cache_k, cache_v, cache_pos


def init_cross_attention(key, d_model: int, n_heads: int, head_dim: int):
    return init_attention(key, d_model, n_heads, n_heads, head_dim)


def cross_attn(p, x, enc_k, enc_v, *, n_heads, head_dim):
    """x: (B, Sq, d); enc_k/enc_v: (B, Se, H, D) precomputed. No mask/RoPE."""
    b, sq, _ = x.shape
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, sq, n_heads, head_dim)
    qg = q.reshape(b, sq, n_heads, 1, head_dim)
    scale = 1.0 / jnp.sqrt(head_dim).astype(jnp.float32)
    mask = jnp.ones((1, 1, 1, 1, 1), bool)
    out = _attend_block(qg, enc_k, enc_v, mask, scale)
    out = out.reshape(b, sq, n_heads * head_dim)
    return out @ p["wo"].astype(x.dtype)


def cross_kv(p, enc_out, *, n_heads, head_dim):
    b, se, _ = enc_out.shape
    k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(b, se, n_heads,
                                                          head_dim)
    v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(b, se, n_heads,
                                                          head_dim)
    return k, v
