"""LM-family model stack for the assigned architectures.

Pure-functional JAX (no flax): parameters are nested dict pytrees,
layer stacks are ``lax.scan`` over stacked (L, ...) weights so HLO size and
compile time stay bounded at 512 devices. See repro.models.model for the
public entry points (init_params / forward_train / prefill / decode_step).
"""

from repro.models.model import (Model, build_model)

__all__ = ["Model", "build_model"]
