"""Decoder/encoder blocks for all assigned families, in scan-stackable form.

A "block" is (init, forward, decode) over a params dict whose leaves can be
stacked with a leading layer axis and driven by ``lax.scan`` (see
transformer.py). Families:

  dense   pre-norm attn + gated MLP           (mistral/gemma/starcoder/qwen/
                                               pixtral backbone)
  moe     pre-norm attn (or MLA) + MoE         (granite, deepseek)
  ssm     mamba2 mixer only                    (mamba2-130m; d_ff = 0)
  hybrid  parallel attn + ssm heads, then MLP  (hymba)
  enc     bidirectional attn + MLP             (whisper encoder)
  xdec    causal self-attn + cross-attn + MLP  (whisper decoder)
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import attention as A
from repro.models.layers import mla as MLA
from repro.models.layers import ssm as S
from repro.models.layers.basic import rms_norm
from repro.models.layers.mlp import init_mlp, mlp
from repro.models.layers.moe import init_moe, moe


def _attn_kwargs(cfg: ArchConfig) -> Dict[str, Any]:
    return dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                qk_norm=cfg.qk_norm, sliding_window=cfg.sliding_window)


def _mla_kwargs(cfg: ArchConfig) -> Dict[str, Any]:
    return dict(n_heads=cfg.n_heads, q_lora=cfg.q_lora_rank,
                kv_lora=cfg.kv_lora_rank, rope_d=cfg.qk_rope_dim,
                nope_d=cfg.qk_nope_dim, v_d=cfg.v_head_dim)


# ------------------------------------------------------------------ init --

def init_block(key, cfg: ArchConfig, kind: str):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Dict[str, Any] = {"ln1": jnp.ones((d,), jnp.float32)}
    if kind == "ssm":
        p["ssm"] = S.init_ssm(ks[0], d, cfg.d_inner, cfg.ssm_state,
                              cfg.ssm_head_p)
        return p
    if kind == "hybrid":
        p["attn"] = A.init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.resolved_head_dim, cfg.qk_norm)
        p["ssm"] = S.init_ssm(ks[3], d, cfg.d_inner, cfg.ssm_state,
                              cfg.ssm_head_p)
    elif cfg.mla and kind in ("dense", "moe"):
        p["attn"] = MLA.init_mla(ks[0], d, **_mla_kwargs(cfg))
    else:
        p["attn"] = A.init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.resolved_head_dim, cfg.qk_norm)
    p["ln2"] = jnp.ones((d,), jnp.float32)
    if kind == "moe":
        p["moe"] = init_moe(ks[1], d, cfg.n_experts, cfg.d_ff,
                            cfg.n_shared_experts, cfg.d_ff)
    else:
        p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, gated=True)
    return p


def init_enc_block(key, cfg: ArchConfig):
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    return {
        "ln1": jnp.ones((d,), jnp.float32),
        "attn": A.init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.resolved_head_dim),
        "ln2": jnp.ones((d,), jnp.float32),
        "mlp": init_mlp(ks[1], d, cfg.d_ff, gated=False),
    }


def init_xdec_block(key, cfg: ArchConfig):
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "ln1": jnp.ones((d,), jnp.float32),
        "attn": A.init_attention(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.resolved_head_dim),
        "lnx": jnp.ones((d,), jnp.float32),
        "xattn": A.init_cross_attention(ks[1], d, cfg.n_heads,
                                        cfg.resolved_head_dim),
        "ln2": jnp.ones((d,), jnp.float32),
        "mlp": init_mlp(ks[2], d, cfg.d_ff, gated=False),
    }


# --------------------------------------------------------------- forward --

def block_forward(p, x, positions, cfg: ArchConfig, kind: str,
                  causal: bool = True):
    """Full-sequence pass. Returns (x, cache, aux) where cache is the
    layer's decode state seed and aux = (lb_loss, z_loss) zeros if non-moe."""
    zero_aux = (jnp.float32(0.0), jnp.float32(0.0))
    h = rms_norm(p["ln1"], x)
    if kind == "ssm":
        out, (ssm_state, conv_state) = S.ssm_forward(
            p["ssm"], h, d_inner=cfg.d_inner, d_state=cfg.ssm_state,
            head_p=cfg.ssm_head_p)
        return x + out, {"ssm": ssm_state, "conv": conv_state}, zero_aux
    if kind == "hybrid":
        a_out, (k, v) = A.attn_forward(p["attn"], h, positions,
                                       causal=causal, **_attn_kwargs(cfg))
        s_out, (ssm_state, conv_state) = S.ssm_forward(
            p["ssm"], h, d_inner=cfg.d_inner, d_state=cfg.ssm_state,
            head_p=cfg.ssm_head_p)
        x = x + 0.5 * (a_out + s_out)
        # ring-buffer KV seed: slot(p) = p % W (see attn_decode_ring)
        w = cfg.sliding_window
        s_len = k.shape[1]
        if s_len >= w:
            shift = (s_len - w) % w
            rk = jnp.roll(k[:, -w:], shift, axis=1)
            rv = jnp.roll(v[:, -w:], shift, axis=1)
            rpos = jnp.roll(jnp.arange(s_len - w, s_len, dtype=jnp.int32),
                            shift)
        else:
            pad = w - s_len
            rk = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            rv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            rpos = jnp.pad(jnp.arange(s_len, dtype=jnp.int32), (0, pad),
                           constant_values=-1)
        cache = {"k": rk, "v": rv, "pos": rpos,
                 "ssm": ssm_state, "conv": conv_state}
    elif cfg.mla:
        a_out, (c_kv, k_rope) = MLA.mla_forward(p["attn"], h, positions,
                                                **_mla_kwargs(cfg))
        x = x + a_out
        cache = {"c_kv": c_kv, "k_rope": k_rope}
    else:
        a_out, (k, v) = A.attn_forward(p["attn"], h, positions,
                                       causal=causal, **_attn_kwargs(cfg))
        x = x + a_out
        cache = {"k": k, "v": v}
    h2 = rms_norm(p["ln2"], x)
    if kind == "moe":
        m_out, aux = moe(p["moe"], h2, n_experts=cfg.n_experts,
                         top_k=cfg.experts_per_token, act=cfg.mlp_act,
                         dispatch=cfg.moe_dispatch)
        return x + m_out, cache, aux
    return x + mlp(p["mlp"], h2, act=cfg.mlp_act), cache, zero_aux


def block_decode(p, x1, cache, pos, cfg: ArchConfig, kind: str):
    """One-token decode. Returns (x1, new_cache)."""
    h = rms_norm(p["ln1"], x1)
    if kind == "ssm":
        out, ssm_state, conv_state = S.ssm_decode(
            p["ssm"], h, cache["ssm"], cache["conv"],
            d_inner=cfg.d_inner, d_state=cfg.ssm_state, head_p=cfg.ssm_head_p)
        return x1 + out, {"ssm": ssm_state, "conv": conv_state}
    if kind == "hybrid":
        a_out, ck, cv, cpos = A.attn_decode_ring(
            p["attn"], h, cache["k"], cache["v"], cache["pos"], pos,
            **_attn_kwargs(cfg))
        s_out, ssm_state, conv_state = S.ssm_decode(
            p["ssm"], h, cache["ssm"], cache["conv"],
            d_inner=cfg.d_inner, d_state=cfg.ssm_state, head_p=cfg.ssm_head_p)
        x1 = x1 + 0.5 * (a_out + s_out)
        cache = {"k": ck, "v": cv, "pos": cpos,
                 "ssm": ssm_state, "conv": conv_state}
    elif cfg.mla:
        a_out, c_kv, k_rope = MLA.mla_decode(p["attn"], h, cache["c_kv"],
                                             cache["k_rope"], pos,
                                             **_mla_kwargs(cfg))
        x1 = x1 + a_out
        cache = {"c_kv": c_kv, "k_rope": k_rope}
    else:
        a_out, ck, cv = A.attn_decode(p["attn"], h, cache["k"], cache["v"],
                                      pos, **_attn_kwargs(cfg))
        x1 = x1 + a_out
        cache = {"k": ck, "v": cv}
    h2 = rms_norm(p["ln2"], x1)
    if kind == "moe":
        m_out, _ = moe(p["moe"], h2, n_experts=cfg.n_experts,
                       top_k=cfg.experts_per_token, act=cfg.mlp_act,
                       dispatch=cfg.moe_dispatch)
        return x1 + m_out, cache
    return x1 + mlp(p["mlp"], h2, act=cfg.mlp_act), cache


def enc_block_forward(p, x, positions, cfg: ArchConfig):
    h = rms_norm(p["ln1"], x)
    out, _ = A.attn_forward(p["attn"], h, positions, causal=False,
                            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                            head_dim=cfg.resolved_head_dim,
                            rope_theta=cfg.rope_theta)
    x = x + out
    return x + mlp(p["mlp"], rms_norm(p["ln2"], x), act=cfg.mlp_act)


def xdec_block_forward(p, x, positions, enc_k, enc_v, cfg: ArchConfig):
    """Whisper decoder full-seq pass; returns (x, self_cache)."""
    h = rms_norm(p["ln1"], x)
    a_out, (k, v) = A.attn_forward(p["attn"], h, positions, causal=True,
                                   n_heads=cfg.n_heads,
                                   n_kv_heads=cfg.n_kv_heads,
                                   head_dim=cfg.resolved_head_dim,
                                   rope_theta=cfg.rope_theta)
    x = x + a_out
    x = x + A.cross_attn(p["xattn"], rms_norm(p["lnx"], x), enc_k, enc_v,
                         n_heads=cfg.n_heads, head_dim=cfg.resolved_head_dim)
    return x + mlp(p["mlp"], rms_norm(p["ln2"], x), act=cfg.mlp_act), \
        {"k": k, "v": v}


def xdec_block_decode(p, x1, cache, enc_k, enc_v, pos, cfg: ArchConfig):
    h = rms_norm(p["ln1"], x1)
    a_out, ck, cv = A.attn_decode(p["attn"], h, cache["k"], cache["v"], pos,
                                  n_heads=cfg.n_heads,
                                  n_kv_heads=cfg.n_kv_heads,
                                  head_dim=cfg.resolved_head_dim,
                                  rope_theta=cfg.rope_theta)
    x1 = x1 + a_out
    x1 = x1 + A.cross_attn(p["xattn"], rms_norm(p["lnx"], x1), enc_k, enc_v,
                           n_heads=cfg.n_heads,
                           head_dim=cfg.resolved_head_dim)
    x1 = x1 + mlp(p["mlp"], rms_norm(p["ln2"], x1), act=cfg.mlp_act)
    return x1, {"k": ck, "v": cv}
