"""Shared benchmark plumbing.

Every paper table/figure gets one module; each emits CSV rows
``name,us_per_call,derived`` where ``us_per_call`` is mean wall-time per
graph (microseconds) and ``derived`` packs the paper's actual metrics
(convergence %, rounds, speedups, KL).

Scale note: the paper benchmarks a V100; this container is one CPU core, so
default sizes are scaled down (Ising 50x50 instead of 100/200, chain 10^4
instead of 10^5) and ``--full`` restores paper scale. Round counts and
convergence rates -- the hardware-independent quantities -- are the primary
reproduction targets; wall-clock ratios are secondary on CPU.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable, List, Sequence

import jax
import numpy as np

from repro.core import run_bp
from repro.core.graph import PGM


@dataclasses.dataclass
class RunStat:
    converged: bool
    rounds: int
    wall_s: float
    updates: float


def time_bp(pgm: PGM, scheduler, *, eps: float = 1e-3, max_rounds: int = 4000,
            seed: int = 0, update_fn=None) -> RunStat:
    kwargs = {} if update_fn is None else dict(update_fn=update_fn)
    # compile first (compile time is not a paper metric)
    res = run_bp(pgm, scheduler, jax.random.key(seed), eps=eps,
                 max_rounds=max_rounds, **kwargs)
    jax.block_until_ready(res.logm)
    t0 = time.perf_counter()
    res = run_bp(pgm, scheduler, jax.random.key(seed), eps=eps,
                 max_rounds=max_rounds, **kwargs)
    jax.block_until_ready(res.logm)
    wall = time.perf_counter() - t0
    return RunStat(bool(res.converged), int(res.rounds), wall,
                   float(res.updates))


def summarize(stats: Sequence[RunStat]) -> dict:
    conv = [s for s in stats if s.converged]
    return dict(
        conv_pct=100.0 * len(conv) / max(len(stats), 1),
        mean_rounds=float(np.mean([s.rounds for s in conv])) if conv else -1.0,
        mean_wall_s=float(np.mean([s.wall_s for s in conv])) if conv else -1.0,
        mean_updates=float(np.mean([s.updates for s in conv])) if conv else -1.0,
    )


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def graph_set(factory: Callable[[int], PGM], n: int) -> List[PGM]:
    return [factory(seed) for seed in range(n)]
