"""Shared benchmark plumbing.

Every paper table/figure gets one module; each emits CSV rows
``name,us_per_call,derived`` where ``us_per_call`` is mean wall-time per
graph (microseconds) and ``derived`` packs the paper's actual metrics
(convergence %, rounds, speedups, KL).

Scale note: the paper benchmarks a V100; this container is one CPU core, so
default sizes are scaled down (Ising 50x50 instead of 100/200, chain 10^4
instead of 10^5) and ``--full`` restores paper scale. Round counts and
convergence rates -- the hardware-independent quantities -- are the primary
reproduction targets; wall-clock ratios are secondary on CPU.
"""

from __future__ import annotations

import dataclasses
import pathlib
import time
from typing import Callable, Iterable, List, Sequence

import jax
import numpy as np

from repro.core import BPConfig, BPEngine
from repro.core.graph import PGM


def out_path(filename: str) -> pathlib.Path:
    """Benchmark artifacts go to ``benchmarks/out/`` (gitignored), not the
    repo root; CI uploads from here."""
    d = pathlib.Path(__file__).resolve().parent / "out"
    d.mkdir(exist_ok=True)
    return d / filename


@dataclasses.dataclass
class RunStat:
    converged: bool
    rounds: int
    wall_s: float
    updates: int


def engine_for(scheduler, *, eps: float = 1e-3, max_rounds: int = 4000,
               update_fn=None, **cfg) -> BPEngine:
    """One engine per (scheduler, backend): keeps jit caches warm across
    timed calls."""
    return BPEngine(BPConfig(scheduler=scheduler, eps=eps,
                             max_rounds=max_rounds,
                             backend=update_fn if update_fn else "ref",
                             **cfg))


def time_bp(pgm: PGM, scheduler, *, eps: float = 1e-3, max_rounds: int = 4000,
            seed: int = 0, update_fn=None) -> RunStat:
    engine = engine_for(scheduler, eps=eps, max_rounds=max_rounds,
                        update_fn=update_fn)
    # compile first (compile time is not a paper metric)
    res = engine.run(pgm, jax.random.key(seed))
    jax.block_until_ready(res.logm)
    t0 = time.perf_counter()
    res = engine.run(pgm, jax.random.key(seed))
    jax.block_until_ready(res.logm)
    wall = time.perf_counter() - t0
    return RunStat(bool(res.converged), int(res.rounds), wall,
                   int(res.updates))


def summarize(stats: Sequence[RunStat]) -> dict:
    conv = [s for s in stats if s.converged]
    return dict(
        conv_pct=100.0 * len(conv) / max(len(stats), 1),
        mean_rounds=float(np.mean([s.rounds for s in conv])) if conv else -1.0,
        mean_wall_s=float(np.mean([s.wall_s for s in conv])) if conv else -1.0,
        mean_updates=float(np.mean([s.updates for s in conv])) if conv else -1.0,
    )


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def graph_set(factory: Callable[[int], PGM], n: int) -> List[PGM]:
    return [factory(seed) for seed in range(n)]


def mixed_graph_set(n: int, *, grid_lo: int = 6, chain_lo: int = 50,
                    chain_step: int = 15) -> List[PGM]:
    """n mixed-size grid/chain graphs with (nearly) all-distinct shapes --
    the serving-stream workload the batched engine buckets. Half grids of
    growing side, half chains of growing length."""
    from repro.pgm import chain_graph, ising_grid
    half = n // 2
    return ([ising_grid(grid_lo + i, 2.0, seed=i) for i in range(half)]
            + [chain_graph(chain_lo + chain_step * i, seed=i)
               for i in range(n - half)])


def time_serving_loop(pgms: Sequence[PGM], scheduler, rng, *,
                      eps: float = 1e-3, max_rounds: int = 2000) -> float:
    """Wall time of the naive per-request loop (one ``engine.run`` per
    graph, blocking each -- exactly what examples/bp_serving.py did
    pre-batching). Includes any compile time the loop triggers, as serving
    would."""
    engine = engine_for(scheduler, eps=eps, max_rounds=max_rounds,
                        history=False)
    t0 = time.perf_counter()
    for i, pgm in enumerate(pgms):
        res = engine.run(pgm, jax.random.fold_in(rng, i))
        jax.block_until_ready(res.logm)
    return time.perf_counter() - t0


def time_serving_batched(pgms: Sequence[PGM], scheduler, rng, *,
                         growth: float = 2.0, eps: float = 1e-3,
                         max_rounds: int = 2000) -> float:
    """Wall time of the bucketed batched engine over the same stream."""
    engine = engine_for(scheduler, eps=eps, max_rounds=max_rounds,
                        history=False)
    t0 = time.perf_counter()
    res = engine.run_many(pgms, rng, growth=growth)
    jax.block_until_ready(res[-1].logm)
    return time.perf_counter() - t0
