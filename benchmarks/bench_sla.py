"""SLA serving: SLO-attainment under deterministic overload, per policy.

The scenario is the one the ``deadline`` admission policy exists for,
built so every number is a pure function of scheduling decisions (no wall
clock anywhere -- a :class:`~repro.core.SweepClock` makes one virtual
second per device sweep, and the whole stream is staged at t=0):

- **fast** requests (6x6 Ising, C=1.5, ~15-25 LBP rounds) with a generous
  latency budget -- they only miss if something blocks the device;
- **express** requests (same easy graphs, ~20 rounds) arriving *behind*
  the fast backlog with a very tight budget -- attainable only under
  earliest-slack-first admission; FIFO serves them in arrival order
  (too late) and residual orders by expected effort, which puts these
  cheap graphs last;
- **heavy-but-feasible** requests (C=2.2/2.5 seeds chosen for ~75-100
  rounds) with a *tight* budget that is attainable only if they are
  served before the fast backlog -- the earliest-slack-first payoff;
- **impossible** requests (C=3.5 seeds that never converge within
  ``max_rounds``) with a moderate budget -- under any non-evicting policy
  they burn ``max_rounds`` rounds of device time and miss anyway; the
  deadline policy detects the stalled residual decay after two chunk
  syncs and evicts them early, freeing their lanes for work that can
  still make its SLO.

Arrival order puts the impossible pair first (head-of-line blocking for
FIFO), then the fast backlog, then the express pair, then the heavies --
so ``fifo`` and ``windowed`` serve express and heavies last (miss),
``residual`` orders by expected effort which serves the high-residual
heavies early but the cheap express graphs last (miss), and only
``deadline`` admits by slack (express and heavies early), evicts the
impossible pair, and lands strictly more requests inside their budgets. The emitted attainment / eviction columns land in
``BENCH_sla.json`` with a ``deadline_strictly_best`` acceptance flag;
latency percentiles are reported over *completed* records only
(``status="completed"`` -- evicted stragglers would shrink them).
"""

from __future__ import annotations

import json
import platform
import time

import jax

from benchmarks.common import emit, out_path
from repro.core import BPConfig, BPEngine, SweepClock, serve_async
from repro.pgm import ising_grid

POLICIES = ("fifo", "residual", "windowed", "deadline")
SLO_FAST = 2500.0       # virtual seconds (device sweeps)
SLO_EXPRESS = 150.0
SLO_HEAVY = 600.0
SLO_IMPOSSIBLE = 300.0
PIPE = dict(slots=1, max_batch=4, chunk_rounds=16, prefetch=None,
            growth=2.0)


def _stream(n_fast: int):
    """(rid, pgm, slo) overload stream: impossible pair first, the fast
    backlog, then the express pair and heavies last. Seeds are pinned to
    their measured round counts (see module docstring); every run is
    identical graph for graph."""
    items = [ising_grid(6, 3.5, seed=0), ising_grid(6, 3.5, seed=2)]
    slos = [SLO_IMPOSSIBLE, SLO_IMPOSSIBLE]
    for s in range(n_fast):
        items.append(ising_grid(6, 1.5, seed=s))
        slos.append(SLO_FAST)
    items += [ising_grid(6, 1.5, seed=10), ising_grid(6, 1.5, seed=11)]
    slos += [SLO_EXPRESS, SLO_EXPRESS]
    items += [ising_grid(6, 2.2, seed=0), ising_grid(6, 2.5, seed=4)]
    slos += [SLO_HEAVY, SLO_HEAVY]
    return [(i, pgm, slo) for i, (pgm, slo) in enumerate(zip(items, slos))]


def run(full: bool = False, n_graphs: int = 0, tiny: bool = False) -> None:
    """Emit per-policy SLO-attainment rows; write BENCH_sla.json."""
    n_fast = n_graphs - 6 if n_graphs else (6 if tiny else 10)
    max_rounds = 160 if tiny else 240
    cfg = BPConfig(scheduler="lbp", eps=1e-5, max_rounds=max_rounds,
                   history=False)
    engine = BPEngine(cfg)
    stream = _stream(n_fast)
    rng = jax.random.key(0)

    record = {
        "suite": "sla", "graphs": len(stream), "max_rounds": max_rounds,
        "slo": {"fast": SLO_FAST, "express": SLO_EXPRESS,
                "heavy": SLO_HEAVY, "impossible": SLO_IMPOSSIBLE},
        "backend": jax.default_backend(), "platform": platform.machine(),
        "unix_time": time.time(),
        "note": ("virtual-time overload scenario (SweepClock: 1 s per "
                 "device sweep, stream staged at t=0), so attainment and "
                 "eviction columns are machine-independent; wall_s is the "
                 "only hardware-dependent field"),
        "policies": {},
    }

    for policy in POLICIES:
        serve_async(engine, iter(stream), rng, admission=policy,
                    clock=SweepClock(), **PIPE)            # warm/compile
        clock = SweepClock()
        t0 = time.perf_counter()
        rep = serve_async(engine, iter(stream), rng, admission=policy,
                          clock=clock, **PIPE)
        wall = time.perf_counter() - t0
        n = len(rep.records)
        attained = sum(1 for r in rep.records if r.within_slo)
        pct = 100.0 * attained / n
        p = rep.latency_percentiles((50, 95), status="completed")
        emit(f"sla/{policy}", 1e6 * wall / n,
             f"slo_attained={attained}/{n};attainment_pct={pct:.1f};"
             f"evictions={rep.stats.evictions};"
             f"evicted_sweeps={rep.stats.evicted_sweeps};"
             f"virtual_makespan={clock.t:.0f}")
        record["policies"][policy] = {
            "attained": attained, "total": n, "attainment_pct": pct,
            "evictions": rep.stats.evictions,
            "evicted_sweeps": rep.stats.evicted_sweeps,
            "completed": sum(1 for r in rep.records if not r.evicted),
            "device_sweeps": rep.stats.device_sweeps,
            "virtual_makespan_s": clock.t,
            "completed_p50_ms": p["p50"], "completed_p95_ms": p["p95"],
            "wall_s": wall,
        }

    pols = record["policies"]
    best = pols["deadline"]["attainment_pct"]
    others = {k: v["attainment_pct"] for k, v in pols.items()
              if k != "deadline"}
    record["deadline_strictly_best"] = bool(
        all(best > v for v in others.values()))
    emit("sla/acceptance", 0.0,
         f"deadline={best:.1f};"
         + ";".join(f"{k}={v:.1f}" for k, v in sorted(others.items()))
         + f";strictly_best={record['deadline_strictly_best']}")

    with open(out_path("BENCH_sla.json"), "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv, tiny="--tiny" in sys.argv)
