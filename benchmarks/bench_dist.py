"""Distributed BP: single-device vs 8-forced-host-device sweep throughput.

The device count is locked at first jax use, so the measurements run in a
child process with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(the same trick the dist tests use); the parent relays the CSV rows. Three
paths over the same graphs, all LBP (deterministic, so sweeps/sec is the
clean unit):

- **single**: the engine's reference backend on one device,
- **sharded**: ``repro.dist`` shard_map backend, edge axis over 8 shards
  (one (V, S) psum per round),
- **banded**: ``repro.dist.bp_banded`` halo-exchange path, 8 contiguous
  bands (neighbor-only ppermute per round) -- plus its round-count parity
  vs the reference, the correctness invariant the speed numbers ride on.

On a 1-2 core CI host the 8 "devices" share the same silicon, so sharding
adds collective overhead without adding FLOPs -- expect <= 1x, like the
warm-batch numbers in BENCH_batch.json. The numbers are recorded anyway
(``benchmarks/out/BENCH_dist.json``, uploaded as a CI artifact) so the
trajectory is honest and a real multi-chip run slots into the same file.
"""

from __future__ import annotations

import json
import os
import platform
import subprocess
import sys
import time


def _child(full: bool) -> None:
    import jax
    from benchmarks.common import emit, out_path
    from repro.core import BPConfig, BPEngine, LBP
    from repro.dist import make_bp_mesh, make_sharded_engine, shard_pgm
    from repro.dist.bp_banded import partition_banded, run_bp_banded
    from repro.pgm import chain_graph, ising_grid_fast

    grid_n = 48 if full else 32
    chain_n = 20000 if full else 4000
    budget = 512 if full else 192        # sweep budget per measurement
    eps = 1e-12                          # unreachable: pin the round count
    mesh = make_bp_mesh()
    n_dev = int(mesh.devices.size)

    record = {
        "suite": "dist", "devices": n_dev,
        "backend": jax.default_backend(), "platform": platform.machine(),
        "unix_time": time.time(), "graphs": {},
    }

    def timed(fn):
        out = fn()                       # warm-up/compile
        jax.block_until_ready(out[0])
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out[0])
        return out, time.perf_counter() - t0

    for gname, pgm in [(f"ising{grid_n}", ising_grid_fast(grid_n, 2.5,
                                                          seed=0)),
                       (f"chain{chain_n}", chain_graph(chain_n, seed=0))]:
        single = BPEngine(BPConfig(scheduler="lbp", eps=eps,
                                   max_rounds=budget, history=False))
        (res, wall_1) = timed(lambda: (single.run(pgm, jax.random.key(0))
                                       .rounds,))
        rounds_1 = int(res[0])   # == budget unless the run hit a fixed point

        shard_eng = make_sharded_engine("lbp", mesh, eps=eps,
                                        max_rounds=budget, history=False)
        spgm = shard_pgm(pgm, mesh)
        (res_s, wall_s) = timed(lambda: (shard_eng.run(
            spgm, jax.random.key(0)).rounds,))
        rounds_s = int(res_s[0])

        part = partition_banded(pgm, n_dev)
        (out_b, wall_b) = timed(lambda: run_bp_banded(
            part, LBP(), mesh, jax.random.key(0), eps=eps,
            max_rounds=budget))
        rounds_b = int(out_b[1])

        # Round-parity spot check at a realistic eps (the invariant
        # TestBandedBP pins; cheap enough to keep in the bench).
        ref = BPEngine(BPConfig(scheduler="lbp", eps=1e-5, max_rounds=6000,
                                history=False)).run(pgm, jax.random.key(0))
        _, rounds_par, done_par = run_bp_banded(
            part, LBP(), mesh, jax.random.key(0), eps=1e-5, max_rounds=6000)
        parity = bool(done_par) and int(rounds_par) == int(ref.rounds)

        sps = {"single": rounds_1 / wall_1, "sharded": rounds_s / wall_s,
               "banded": rounds_b / wall_b}
        for path, v in sps.items():
            emit(f"dist/{gname}/{path}", 1e6 / v,
                 f"sweeps_per_s={v:.1f};speedup_vs_single="
                 f"{v / sps['single']:.2f}")
        emit(f"dist/{gname}/banded_round_parity", 0.0,
             f"match={parity};rounds={int(rounds_par)}")
        record["graphs"][gname] = {
            "edges": pgm.n_real_edges, "sweeps": rounds_1,
            "single_sweeps_per_s": sps["single"],
            "sharded_sweeps_per_s": sps["sharded"],
            "banded_sweeps_per_s": sps["banded"],
            "sharded_speedup": sps["sharded"] / sps["single"],
            "banded_speedup": sps["banded"] / sps["single"],
            "banded_round_parity": parity,
        }

    with open(out_path("BENCH_dist.json"), "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")


def run(full: bool = False, n_graphs: int = 0) -> None:
    """Parent entry (benchmarks.run registry): re-exec in a child with 8
    forced host devices and relay its output."""
    del n_graphs
    env = dict(os.environ,
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "")
                          + " --xla_force_host_platform_device_count=8"))
    cmd = [sys.executable, "-m", "benchmarks.bench_dist", "--child"]
    if full:
        cmd.append("--full")
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=3600)
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        sys.stderr.write(out.stderr[-4000:])
        raise RuntimeError("bench_dist child failed")


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child("--full" in sys.argv)
    else:
        run("--full" in sys.argv)
