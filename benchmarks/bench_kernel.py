"""Kernel microbenchmark: fused Pallas message update vs pure-jnp reference.

Wall time on CPU (interpret mode) is not the TPU story; the meaningful
numbers are the HLO cost-analysis FLOPs/bytes of one BP round for each path,
which feed the BP roofline in EXPERIMENTS.md. Both are reported."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import messages as M
from repro.kernels.ops import pallas_update
from repro.pgm import ising_grid, protein_like_graph

from benchmarks.common import emit


def _cost(fn, *args):
    c = jax.jit(fn).lower(*args).compile().cost_analysis()
    return c.get("flops", 0.0), (c.get("bytes accessed", 0.0) or
                                 sum(v for k, v in c.items()
                                     if k.startswith("bytes accessed")))


def run(full: bool = False, n_graphs: int = 1) -> None:
    for name, pgm in [("ising40_S2", ising_grid(40, 2.5)),
                      ("protein100_S~64", protein_like_graph(100, seed=1))]:
        logm = M.init_messages(pgm)
        for path, fn in [("ref", M.ref_update),
                         ("pallas_interp",
                          lambda p, m: pallas_update(p, m, interpret=True))]:
            out = fn(pgm, logm)
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            for _ in range(5):
                out = fn(pgm, logm)
                jax.block_until_ready(out)
            us = (time.perf_counter() - t0) / 5 * 1e6
            try:
                flops, byts = _cost(fn, pgm, logm)
            except Exception:
                flops = byts = float("nan")
            emit(f"kernel/{name}/{path}", us,
                 f"hlo_flops={flops:.3e};hlo_bytes={byts:.3e};"
                 f"E={pgm.n_edges};S={pgm.n_states_max}")
