"""Kernel microbenchmark: fused message update, roofline-verified.

Four sections, written to ``benchmarks/out/BENCH_kernel.json`` (and a
committed root copy, since ``benchmarks/out/`` is gitignored):

- **kernel**: predicted vs measured cost of one fused GPU-layout update
  (``repro.kernels.triton_update.fused_update_e``) per workload shape and
  semiring. "Predicted" is the hand 3-read/2-write model
  (``repro.roofline.kernel_model``); "measured" is the jaxpr-walk of the
  actual launch (``repro.roofline.trace_cost``), padded shapes and all.
  ``prediction_within_tolerance`` is the acceptance column: the two
  intensities must agree within ``_RTOL``.
- **schedulers**: the same predicted-vs-measured kernel intensity recorded
  per registered scheduler, plus the *round* intensity from tracing one
  full engine round (update + residual gate + frontier select + commit)
  with that scheduler -- i.e. how much each scheduler's selection machinery
  dilutes the kernel's arithmetic intensity.
- **autotune**: ``autotune_blk_e`` wall-time sweep vs the analytic
  ``pick_block_edges_gpu`` choice. On CPU (interpret mode) wall time is
  not the GPU story, so the recorded claim is only that the model pick is
  admissible (a swept candidate); on a real GPU the sweep re-runs there.
- **walltime**: ref vs pallas-interpret vs triton-interpret microseconds
  for one update call (CPU sanity numbers, not the accelerator story).

Usage: python -m benchmarks.bench_kernel [--tiny | --full]
"""

from __future__ import annotations

import json
import pathlib
import platform
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, out_path
from repro.core import messages as M
from repro.core.schedulers import get_scheduler, list_schedulers
from repro.kernels.ops import make_triton_update, pallas_update, triton_update
from repro.kernels.triton_update import autotune_blk_e, fused_update_e
from repro.pgm import ising_grid, protein_like_graph
from repro.roofline import (fused_update_cost, gpu_padded_shape,
                            predicted_intensity, round_cost, trace_cost)

_RTOL = 0.10   # predicted-vs-measured intensity agreement (acceptance)


def _operands(e, s, dtype=jnp.float32):
    return (jax.ShapeDtypeStruct((e, s, s), dtype),
            jax.ShapeDtypeStruct((e, s), dtype),
            jax.ShapeDtypeStruct((e, s), dtype),
            jax.ShapeDtypeStruct((e, s), jnp.bool_))


def _kernel_row(e, s, *, dtype=jnp.float32, semiring="sum"):
    """Predicted (hand model) vs measured (jaxpr walk) for one launch.

    The trace runs at the *launch* shapes (states to the next power of two,
    edges to a block multiple) -- the kernel the GPU executes -- so the
    host-side pad/slice glue XLA fuses around it is not billed to the
    kernel. The model predicts the same padded launch (``padded=True``).
    """
    db = jnp.dtype(dtype).itemsize
    e_pad, s_pad, blk = gpu_padded_shape(e, s, db)
    meas = trace_cost(lambda *o: fused_update_e(
        *o, semiring=semiring, interpret=True), *_operands(e_pad, s_pad, dtype))
    pred = fused_update_cost(e, s, dtype_bytes=db, semiring=semiring,
                             padded=True)
    mi, pi = meas.flops / meas.bytes, pred.flops / pred.bytes
    rel = abs(mi - pi) / pi
    return dict(n_edges=e, n_states=s, e_pad=e_pad, s_pad=s_pad, blk_e=blk,
                dtype=str(jnp.dtype(dtype)), semiring=semiring,
                predicted_flops=pred.flops, predicted_bytes=pred.bytes,
                measured_flops=meas.flops, measured_bytes=meas.bytes,
                predicted_intensity=pi, measured_intensity=mi,
                intensity_rel_err=rel,
                prediction_within_tolerance=bool(rel <= _RTOL))


def _time_update(fn, pgm, logm, iters=5):
    out = fn(pgm, logm)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(pgm, logm)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(full: bool = False, n_graphs: int = 1, tiny: bool = False) -> None:
    if tiny:
        cases = [("ising6_S2", ising_grid(6, 2.0))]
    elif full:
        cases = [("ising40_S2", ising_grid(40, 2.5)),
                 ("protein100_S~64", protein_like_graph(100, seed=1))]
    else:
        cases = [("ising16_S2", ising_grid(16, 2.0)),
                 ("protein40_S~64", protein_like_graph(40, seed=1))]

    record = {"meta": dict(mode="tiny" if tiny else ("full" if full
                                                     else "default"),
                           jax=jax.__version__,
                           machine=platform.machine(),
                           backend=jax.default_backend(),
                           interpret=True, rtol=_RTOL),
              "kernel": {}, "schedulers": {}, "autotune": {},
              "walltime": {}}

    # -- kernel: predicted vs measured per shape x semiring (+ one bf16) --
    for name, pgm in cases:
        e, s = pgm.n_edges, pgm.n_states_max
        for semiring in ("sum", "max"):
            row = _kernel_row(e, s, semiring=semiring)
            record["kernel"][f"{name}/{semiring}"] = row
            emit(f"kernel/{name}/{semiring}", 0.0,
                 f"pred_ai={row['predicted_intensity']:.3f};"
                 f"meas_ai={row['measured_intensity']:.3f};"
                 f"ok={row['prediction_within_tolerance']}")
    bf = _kernel_row(cases[0][1].n_edges, cases[0][1].n_states_max,
                     dtype=jnp.bfloat16)
    record["kernel"][f"{cases[0][0]}/sum/bf16"] = bf

    # -- schedulers: kernel prediction + round-level dilution ------------
    sched_pgm = cases[0][1]
    e, s = sched_pgm.n_edges, sched_pgm.n_states_max
    kernel_row = record["kernel"][f"{cases[0][0]}/sum"]
    pred_ai = kernel_row["predicted_intensity"]
    meas_ai = kernel_row["measured_intensity"]
    update_fn = make_triton_update(True)
    for sname in list_schedulers():
        rc = round_cost(sched_pgm, get_scheduler(sname), update_fn)
        round_ai = rc.flops / rc.bytes
        rel = abs(meas_ai - pred_ai) / pred_ai
        record["schedulers"][sname] = dict(
            n_edges=e, n_states=s,
            predicted_intensity=pred_ai,
            measured_kernel_intensity=meas_ai,
            measured_round_intensity=round_ai,
            round_flops=rc.flops, round_bytes=rc.bytes,
            kernel_byte_fraction=kernel_row["measured_bytes"] / rc.bytes,
            intensity_rel_err=rel,
            prediction_within_tolerance=bool(rel <= _RTOL))
        emit(f"kernel/sched/{sname}", 0.0,
             f"pred_ai={pred_ai:.3f};meas_ai={meas_ai:.3f};"
             f"round_ai={round_ai:.3f};ok={rel <= _RTOL}")

    # -- autotune: model pick vs wall-time sweep -------------------------
    key = jax.random.key(0)
    _, s_pad, model_blk = gpu_padded_shape(e, s)   # model pick, launch-clamped
    logpsi = jax.random.normal(key, (e, s, s))
    pre = jax.random.normal(jax.random.fold_in(key, 1), (e, s))
    logm = jnp.zeros((e, s))
    dmask = jnp.ones((e, s), dtype=bool)
    best_blk, timings = autotune_blk_e(logpsi, pre, logm, dmask,
                                       interpret=True,
                                       iters=1 if tiny else 3)
    record["autotune"] = dict(
        case=cases[0][0], n_edges=e, n_states=s,
        model_blk=model_blk, best_blk=best_blk,
        model_pick_swept=bool(model_blk in timings),
        target_intensity=predicted_intensity(s, padded=True),
        timings_us={str(k): v for k, v in sorted(timings.items())})
    emit(f"kernel/autotune/{cases[0][0]}", min(timings.values()),
         f"model_blk={model_blk};best_blk={best_blk}")

    # -- walltime: CPU sanity, all three update paths --------------------
    wt_cases = cases if not tiny else cases[:1]
    for name, pgm in wt_cases:
        logm_g = M.init_messages(pgm)
        for path, fn in [
                ("ref", M.ref_update),
                ("pallas_interp",
                 lambda p, m: pallas_update(p, m, interpret=True)),
                ("triton_interp",
                 lambda p, m: triton_update(p, m, interpret=True))]:
            us = _time_update(fn, pgm, logm_g, iters=2 if tiny else 5)
            record["walltime"][f"{name}/{path}"] = us
            emit(f"kernel/{name}/{path}", us,
                 f"E={pgm.n_edges};S={pgm.n_states_max}")

    payload = json.dumps(record, indent=2, sort_keys=True)
    out = out_path("BENCH_kernel.json")
    out.write_text(payload)
    # Committed root copy: benchmarks/out/ is gitignored, and the
    # predicted-vs-measured table is a repo-level claim, not a CI artifact.
    root = pathlib.Path(__file__).resolve().parent.parent / "BENCH_kernel.json"
    root.write_text(payload)
    print(f"# wrote {out} and {root}")


if __name__ == "__main__":
    import sys
    run(full="--full" in sys.argv, tiny="--tiny" in sys.argv)
