"""Paper Fig. 4 + Table III: RnBP vs LBP vs SRBP across difficulty sweep.

Reproduction targets:
  * easy graphs (C=2): RnBP(LowP=0.7) ~ LBP speed (low overhead),
  * hard graphs (C=2.5 large / C=3): RnBP converges where LBP stalls or
    fails, with round-count speedups over LBP,
  * very hard (C=3): only LowP=0.1 converges reliably (convergence mode),
  * all: large speedups over SRBP (Table III).
"""

from __future__ import annotations

from repro.core import BPConfig, BPEngine, LBP, RnBP
from repro.pgm import chain_graph, ising_grid

from benchmarks.common import emit, graph_set, summarize, time_bp


def run(full: bool = False, n_graphs: int = 5) -> None:
    n = 100 if full else 40
    n2 = 200 if full else 60
    chain_n = 100_000 if full else 10_000
    srbp_cap = 90.0 if full else 20.0
    datasets = [
        (f"ising{n}x{n}_C2", lambda s: ising_grid(n, 2.0, seed=s), 6000),
        (f"ising{n}x{n}_C2.5", lambda s: ising_grid(n, 2.5, seed=s), 6000),
        (f"ising{n}x{n}_C3", lambda s: ising_grid(n, 3.0, seed=s), 12000),
        (f"ising{n2}x{n2}_C2.5", lambda s: ising_grid(n2, 2.5, seed=s), 8000),
        (f"chain{chain_n}_C10", lambda s: chain_graph(chain_n, seed=s), 4000),
    ]
    srbp_eng = BPEngine(BPConfig(
        scheduler="srbp", scheduler_kwargs={"time_limit_s": srbp_cap}))
    for dname, factory, max_rounds in datasets:
        graphs = graph_set(factory, n_graphs)
        srbp = [srbp_eng.run(g) for g in graphs]
        srbp_conv = [r for r in srbp if r.converged]
        srbp_t = (sum(r.wall_time_s for r in srbp_conv) / len(srbp_conv)
                  if srbp_conv else srbp_cap)
        bound = "" if srbp_conv else ">"
        emit(f"fig4-tabIII/{dname}/SRBP", srbp_t * 1e6,
             f"conv={100 * len(srbp_conv) // len(srbp)}%")
        for sched_name, sched in [
            ("LBP", LBP()),
            ("RnBP_low0.7", RnBP(low_p=0.7)),
            ("RnBP_low0.4", RnBP(low_p=0.4)),
            ("RnBP_low0.1", RnBP(low_p=0.1)),
        ]:
            stats = [time_bp(g, sched, max_rounds=max_rounds) for g in graphs]
            s = summarize(stats)
            speedup = (srbp_t / s["mean_wall_s"]
                       if s["mean_wall_s"] > 0 else float("nan"))
            emit(f"fig4-tabIII/{dname}/{sched_name}",
                 s["mean_wall_s"] * 1e6,
                 f"conv={s['conv_pct']:.0f}%;rounds={s['mean_rounds']:.0f};"
                 f"srbp_speedup={bound}{speedup:.2f}x")
