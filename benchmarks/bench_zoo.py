"""Workload zoo: LDPC BER curves, stereo decoding, heterogeneous serving.

Three sections, written to ``benchmarks/out/BENCH_zoo.json``:

- **ldpc**: bit-error-rate vs SNR for max-product decoding of regular
  Gallager codes (``repro.pgm.ldpc_code``) against the uncoded
  hard-decision baseline on the same received samples. The acceptance
  number is ``snr_points_beating_uncoded`` -- the decoder must beat
  uncoded transmission at >= 2 SNR points, in ``--tiny`` mode too (a
  decoder that cannot beat no-code is not decoding).
- **stereo**: max-product disparity decoding of the synthetic stereo MRF
  (``repro.pgm.stereo_mrf``): +-1 accuracy vs the raw observation and MAP
  energy vs the ground truth's energy (BP should match or beat truth's
  energy -- the MAP objective is what it optimizes). Plus the banded dist
  path stress: the stereo grid is exactly the contiguous-band shape
  ``repro.dist.bp_banded`` was built for, so the same graph runs through
  ``run_bp_banded`` with its round-count parity vs the single-device
  engine recorded.
- **serving**: the full heterogeneous zoo (``repro.pgm.zoo_stream`` --
  ising/chain/protein/ldpc/stereo at mixed sizes) as one online stream
  through ``serve_async`` (residual and windowed admission) and
  ``serve_routed`` (kind_affinity routing, stealing off/on), with
  *bitwise* per-request parity against solo ``BPEngine.run`` calls on
  identically padded graphs -- the serving tier's determinism contract
  extended to the workload mix it was built for.

Usage: python -m benchmarks.bench_zoo [--tiny | --full]
"""

from __future__ import annotations

import json
import platform
import time

import jax
import numpy as np

from benchmarks.common import emit, out_path
from repro.core import BPConfig, BPEngine, serve_async
from repro.core.batch import bucket_shape
from repro.core.graph import pad_pgm
from repro.core.messages import map_assignment
from repro.pgm import ldpc_code, stereo_mrf, zoo_stream
from repro.serve import serve_routed


def _bench_ldpc(record: dict, *, n: int, words: int, snrs) -> None:
    engine = BPEngine(BPConfig(scheduler="lbp", backend="maxprod",
                               eps=1e-4, max_rounds=400, history=False))
    curve = {}
    beating = 0
    for snr_db in snrs:
        t0 = time.perf_counter()
        coded = uncoded = bits = conv = 0
        rounds = []
        for w in range(words):
            inst = ldpc_code(n, snr_db=snr_db, seed=1000 * w + 7)
            res = engine.run(inst.pgm, jax.random.key(w))
            decoded = np.asarray(map_assignment(inst.pgm, res.logm))
            coded += inst.coded_errors(decoded)
            uncoded += inst.uncoded_errors
            bits += inst.n_bits
            conv += int(bool(res.converged))
            rounds.append(int(res.rounds))
        wall = time.perf_counter() - t0
        cb, ub = coded / bits, uncoded / bits
        beating += int(cb < ub)
        curve[f"{snr_db:g}"] = {
            "coded_ber": cb, "uncoded_ber": ub, "bits": bits,
            "converged": conv, "words": words,
            "mean_rounds": float(np.mean(rounds)), "wall_s": wall,
        }
        emit(f"zoo/ldpc/snr{snr_db:g}", 1e6 * wall / words,
             f"coded_ber={cb:.4f};uncoded_ber={ub:.4f};"
             f"conv={conv}/{words};rounds={np.mean(rounds):.1f}")
    record["ldpc"] = {
        "n": n, "dv": 3, "dc": 6, "curve": curve,
        "snr_points_beating_uncoded": beating,
        "acceptance": "coded BER < uncoded BER at >= 2 SNR points",
    }
    emit("zoo/ldpc/acceptance", 0.0,
         f"snr_points_beating_uncoded={beating};required=2")


def _bench_stereo(record: dict, *, height: int, width: int,
                  n_disp: int) -> None:
    inst = stereo_mrf(height, width, n_disp, seed=0)
    engine = BPEngine(BPConfig(scheduler="rbp", backend="maxprod",
                               eps=1e-4, max_rounds=2000, history=False))
    engine.run(inst.pgm, jax.random.key(0))          # warm/compile
    t0 = time.perf_counter()
    res = engine.run(inst.pgm, jax.random.key(0))
    jax.block_until_ready(res.logm)
    wall = time.perf_counter() - t0
    n_pix = height * width
    labels = np.asarray(map_assignment(inst.pgm, res.logm))[:n_pix]
    obs = np.clip(np.round(inst.obs), 0, n_disp - 1).astype(int)
    acc_bp, acc_obs = inst.accuracy(labels), inst.accuracy(obs)
    e_bp, e_truth = inst.energy(labels), inst.energy(inst.truth)
    emit(f"zoo/stereo/{height}x{width}x{n_disp}", 1e6 * wall,
         f"acc_bp={acc_bp:.3f};acc_obs={acc_obs:.3f};"
         f"energy_bp={e_bp:.2f};energy_truth={e_truth:.2f};"
         f"rounds={int(res.rounds)};conv={bool(res.converged)}")

    # Banded dist stress: the row-major stereo grid is the contiguous-band
    # shape bp_banded exists for; record LBP round parity vs the engine.
    from repro.dist import make_bp_mesh
    from repro.dist.bp_banded import partition_banded, run_bp_banded
    mesh = make_bp_mesh()
    n_bands = int(mesh.devices.size)
    lbp = BPEngine(BPConfig(scheduler="lbp", eps=1e-3, max_rounds=2000,
                            history=False))
    ref = lbp.run(inst.pgm, jax.random.key(0))
    part = partition_banded(inst.pgm, n_bands)
    run_bp_banded(part, "lbp", mesh, jax.random.key(0), eps=1e-3,
                  max_rounds=2000)                   # warm/compile
    t0 = time.perf_counter()
    _, b_rounds, b_done = run_bp_banded(part, "lbp", mesh, jax.random.key(0),
                                        eps=1e-3, max_rounds=2000)
    b_wall = time.perf_counter() - t0
    parity = int(b_rounds) == int(ref.rounds)
    emit(f"zoo/stereo/banded{n_bands}", 1e6 * b_wall,
         f"rounds={int(b_rounds)};round_parity_vs_ref={parity};"
         f"conv={bool(b_done)}")
    record["stereo"] = {
        "height": height, "width": width, "n_disp": n_disp,
        "accuracy_bp": acc_bp, "accuracy_observation": acc_obs,
        "energy_bp": e_bp, "energy_truth": e_truth,
        "energy_observation": inst.energy(obs),
        "rounds": int(res.rounds), "converged": bool(res.converged),
        "wall_s": wall,
        "banded": {"bands": n_bands, "rounds": int(b_rounds),
                   "round_parity_vs_ref": parity, "wall_s": b_wall},
        "acceptance": "energy_bp <= energy_truth and accuracy_bp >= "
                      "accuracy_observation",
    }


def _bench_serving(record: dict, *, n_requests: int) -> None:
    stream = [p for _, p in zoo_stream(n_requests, seed=0)]
    rng = jax.random.key(0)
    engine = BPEngine(BPConfig(scheduler="lbp", backend="maxprod",
                               eps=1e-3, max_rounds=256, history=False))

    def solo(rid):
        # The online pipeline pads each request to its own bucket_shape
        # ceilings; the solo reference must run on the identically padded
        # graph (stochastic schedulers would draw over the padded edge
        # axis, and rounds/updates count over padded shapes).
        e, v, s, re_, rv = bucket_shape(stream[rid], 2.0)
        padded = pad_pgm(stream[rid], n_edges=e, n_vertices=v, n_states=s,
                         n_real_edges=re_, n_real_vertices=rv)
        return engine.run(padded, jax.random.fold_in(rng, rid))

    want = {rid: solo(rid) for rid in range(len(stream))}

    def check(records):
        for rec in records:
            w = want[rec.rid]
            if int(rec.result.rounds) != int(w.rounds):
                return False
            if not np.array_equal(np.asarray(rec.result.logm),
                                  np.asarray(w.logm)):
                return False
        return len(records) == len(stream)

    kw = dict(max_batch=3, chunk_rounds=32, prefetch=4, slots=2)
    record["serving"] = {"requests": len(stream), "configs": {}}
    for policy in ("residual", "windowed"):
        serve_async(engine, iter(stream), rng, admission=policy, **kw)
        t0 = time.perf_counter()
        rep = serve_async(engine, iter(stream), rng, admission=policy, **kw)
        wall = time.perf_counter() - t0
        ok = check(rep.records)
        emit(f"zoo/serve_async/{policy}", 1e6 * wall / len(stream),
             f"graphs_per_s={len(stream) / wall:.2f};bitwise_vs_solo={ok};"
             f"wasted_sweeps={rep.stats.wasted_sweeps}")
        record["serving"]["configs"][f"serve_async/{policy}"] = {
            "wall_s": wall, "bitwise_vs_solo": ok,
            "wasted_sweeps": rep.stats.wasted_sweeps,
            "useful_sweeps": rep.stats.useful_sweeps,
        }
    engines = [BPEngine(engine.config) for _ in range(2)]
    for steal in (False, True):
        serve_routed(engines, iter(stream), rng, routing="kind_affinity",
                     steal=steal, **kw)
        t0 = time.perf_counter()
        rep = serve_routed(engines, iter(stream), rng,
                           routing="kind_affinity", steal=steal, **kw)
        wall = time.perf_counter() - t0
        ok = check(rep.records)
        mode = "steal_on" if steal else "steal_off"
        emit(f"zoo/serve_routed/kind_affinity/{mode}",
             1e6 * wall / len(stream),
             f"graphs_per_s={len(stream) / wall:.2f};bitwise_vs_solo={ok};"
             f"steals={rep.stats.steals};stolen={rep.stats.stolen}")
        record["serving"]["configs"][f"serve_routed/kind_affinity/{mode}"] = {
            "wall_s": wall, "bitwise_vs_solo": ok,
            "steals": rep.stats.steals, "stolen": rep.stats.stolen,
            "wasted_sweeps": rep.wasted_sweeps,
        }
    record["serving"]["bitwise_all"] = all(
        c["bitwise_vs_solo"] for c in record["serving"]["configs"].values())
    record["serving"]["acceptance"] = (
        "every config completes the mixed stream with bitwise per-request "
        "parity vs solo runs")


def run(full: bool = False, n_graphs: int = 0, tiny: bool = False) -> None:
    """Emit the zoo rows and write BENCH_zoo.json. ``tiny`` is the CI
    smoke scale (the acceptance columns must hold there too)."""
    record = {
        "suite": "zoo", "backend": jax.default_backend(),
        "platform": platform.machine(), "unix_time": time.time(),
        "mode": "tiny" if tiny else ("full" if full else "default"),
        "note": ("acceptance: ldpc.snr_points_beating_uncoded >= 2 and "
                 "serving.bitwise_all == true at every scale"),
    }
    if tiny:
        _bench_ldpc(record, n=48, words=4, snrs=(1.0, 2.0, 3.0))
        _bench_stereo(record, height=8, width=12, n_disp=6)
        _bench_serving(record, n_requests=n_graphs or 9)
    elif full:
        _bench_ldpc(record, n=96, words=16,
                    snrs=(0.5, 1.0, 1.5, 2.0, 2.5, 3.0))
        _bench_stereo(record, height=24, width=32, n_disp=12)
        _bench_serving(record, n_requests=n_graphs or 18)
    else:
        _bench_ldpc(record, n=48, words=8, snrs=(1.0, 2.0, 3.0))
        _bench_stereo(record, height=12, width=16, n_disp=8)
        _bench_serving(record, n_requests=n_graphs or 9)

    with open(out_path("BENCH_zoo.json"), "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")


if __name__ == "__main__":
    import sys
    print("name,us_per_call,derived")
    run(full="--full" in sys.argv, tiny="--tiny" in sys.argv)
