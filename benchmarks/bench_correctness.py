"""Paper Fig. 5: marginal quality (KL vs exact) on Ising 10x10, C=2.

Exact marginals by variable elimination; compares SRBP and RnBP(LowP=0.7).
Reproduction target: RnBP matches SRBP quality (both are loopy-BP fixed
points; the scheduler must not change the answer)."""

from __future__ import annotations

import numpy as np

import jax

from repro.core import BPConfig, BPEngine, kl_divergence, ve_marginals
from repro.pgm import small_ising

from benchmarks.common import emit


def run(full: bool = False, n_graphs: int = 5) -> None:
    rnbp = BPEngine(BPConfig(scheduler="rnbp", scheduler_kwargs={"low_p": 0.7},
                             eps=1e-5, max_rounds=4000))
    srbp = BPEngine(BPConfig(scheduler="srbp", eps=1e-5))
    for seed in range(n_graphs):
        pgm, nv, edges, unary, pairwise = small_ising(10, 2.0, seed=seed)
        exact = ve_marginals(nv, edges, unary, pairwise)
        res = rnbp.run(pgm, jax.random.key(seed))
        b = np.exp(np.asarray(res.beliefs))[:nv, :2]
        kl_rnbp = [kl_divergence(exact[v], b[v]) for v in range(nv)]
        sr = srbp.run(pgm)
        bs = np.exp(sr.beliefs)[:nv, :2]
        kl_srbp = [kl_divergence(exact[v], bs[v]) for v in range(nv)]
        emit(f"fig5/ising10x10_C2_seed{seed}/RnBP", 0.0,
             f"meanKL={np.mean(kl_rnbp):.2e};maxKL={np.max(kl_rnbp):.2e}")
        emit(f"fig5/ising10x10_C2_seed{seed}/SRBP", 0.0,
             f"meanKL={np.mean(kl_srbp):.2e};maxKL={np.max(kl_srbp):.2e}")
