"""Paper Tables I & II: bulk-parallel RBP / RS speedup over serial RBP.

The paper gives SRBP 90 s before declaring non-convergence and reports
conservative lower-bound speedups in that case; we do the same (scaled cap
off-``--full``).
"""

from __future__ import annotations

from repro.core import BPConfig, BPEngine, RBP, RS
from repro.pgm import chain_graph, ising_grid

from benchmarks.common import emit, graph_set, summarize, time_bp


def run(full: bool = False, n_graphs: int = 3) -> None:
    n = 100 if full else 40
    chain_n = 100_000 if full else 10_000
    srbp_cap = 90.0 if full else 20.0
    datasets = [
        (f"ising{n}x{n}_C2.5", lambda s: ising_grid(n, 2.5, seed=s),
         1.0 / 256, 1.0 / 128),
        (f"chain{chain_n}_C10", lambda s: chain_graph(chain_n, seed=s),
         1.0 / 16, 1.0 / 16),
    ]
    srbp_eng = BPEngine(BPConfig(
        scheduler="srbp", scheduler_kwargs={"time_limit_s": srbp_cap}))
    for dname, factory, p_rbp, p_rs in datasets:
        graphs = graph_set(factory, n_graphs)
        srbp = [srbp_eng.run(g) for g in graphs]
        srbp_conv = [r for r in srbp if r.converged]
        srbp_t = (sum(r.wall_time_s for r in srbp_conv) / len(srbp_conv)
                  if srbp_conv else srbp_cap)
        bound = "" if srbp_conv else ">"
        emit(f"tableI-II/{dname}/SRBP", srbp_t * 1e6,
             f"conv={100 * len(srbp_conv) // len(srbp)}%")
        for sched_name, sched in [(f"RBP_p{p_rbp:.4f}", RBP(p=p_rbp)),
                                  (f"RS_p{p_rs:.4f}", RS(p=p_rs))]:
            stats = [time_bp(g, sched, max_rounds=8000) for g in graphs]
            s = summarize(stats)
            speedup = (srbp_t / s["mean_wall_s"]
                       if s["mean_wall_s"] > 0 else float("nan"))
            emit(f"tableI-II/{dname}/{sched_name}", s["mean_wall_s"] * 1e6,
                 f"conv={s['conv_pct']:.0f}%;rounds={s['mean_rounds']:.0f};"
                 f"srbp_speedup={bound}{speedup:.2f}x")
