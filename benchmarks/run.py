"""Benchmark driver: one module per paper table/figure.

Usage:  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig2,...]

Emits ``name,us_per_call,derived`` CSV rows (see benchmarks.common).
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (bench_batch, bench_correctness, bench_dist,
                        bench_greedy, bench_kernel, bench_protein,
                        bench_rnbp, bench_router, bench_sla,
                        bench_tradeoff, bench_zoo)

SUITES = {
    "fig2_tradeoff": bench_tradeoff,
    "tableI-II_greedy": bench_greedy,
    "fig4_tableIII_rnbp": bench_rnbp,
    "fig5_correctness": bench_correctness,
    "protein": bench_protein,
    "kernel": bench_kernel,
    "batch": bench_batch,
    "dist": bench_dist,
    "router": bench_router,
    "sla": bench_sla,
    "zoo": bench_zoo,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow on CPU)")
    ap.add_argument("--only", type=str, default="",
                    help="comma-separated suite filter")
    ap.add_argument("--graphs", type=int, default=0,
                    help="override graphs per dataset")
    args = ap.parse_args()
    only = [s for s in args.only.split(",") if s]
    print("name,us_per_call,derived")
    for name, mod in SUITES.items():
        if only and not any(o in name for o in only):
            continue
        t0 = time.perf_counter()
        kwargs = {}
        if args.graphs:
            kwargs["n_graphs"] = args.graphs
        mod.run(full=args.full, **kwargs)
        print(f"# suite {name} done in {time.perf_counter() - t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
