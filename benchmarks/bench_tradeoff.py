"""Paper Fig. 2 + the relaxation axis: scheduling <-> convergence trade-off.

Two sweeps over the same hard instances (high-coupling Ising grids, the
regime where LBP oscillates and scheduling decides convergence):

1. **fig2** (the original reproduction): frontier multiplier ``p`` for
   Residual Splash vs LBP -- lower p => more graphs converge, slower.
2. **relaxation** (arxiv 2002.11505): the rlx family's relaxation degree
   (``queues`` x ``sample`` fraction) against converged-fraction and
   rounds-to-converge, with exact RBP as the quality baseline. The paper's
   claim under test: relaxed multi-queue selection tracks exact residual
   scheduling's convergence (acceptance: rlx converged-fraction within 10%
   of RBP's) while replacing the global top-k with shard-local per-queue
   sorts.

The relaxation section also runs a **collective audit** in an 8-forced-
host-device child (same trick as ``bench_dist``): one BP round (sharded
update + frontier select + commit) is jitted and compiled for rbp and rlx
under ``backend="sharded"``, and the optimized HLO is scanned for
cross-shard data movement (``all-gather``/``all-to-all``). RBP's exact
global top-k forces the residual vector to be gathered across shards;
rlx's per-queue top-k must not -- ``eliminates_global_topk`` in the JSON
records exactly that, from the compiled artifact rather than from intent.

Everything lands in ``benchmarks/out/BENCH_tradeoff.json`` (uploaded as a
CI artifact). ``--tiny`` runs a minutes-scale smoke sweep (CI); ``--full``
restores paper scale.
"""

from __future__ import annotations

import json
import os
import platform
import subprocess
import sys
import time


# ------------------------------------------------------------- fig2 sweep --

def _fig2(full: bool, n_graphs: int) -> None:
    from repro.core import LBP, RS
    from repro.pgm import chain_graph, ising_grid

    from benchmarks.common import emit, graph_set, summarize, time_bp

    n = 100 if full else 40
    chain_n = 100_000 if full else 10_000
    datasets = [
        (f"ising{n}x{n}_C2.5", lambda s: ising_grid(n, 2.5, seed=s)),
        (f"chain{chain_n}_C10", lambda s: chain_graph(chain_n, seed=s)),
    ]
    max_rounds = 8000 if full else 4000
    for dname, factory in datasets:
        graphs = graph_set(factory, n_graphs)
        for sched_name, sched in [
            ("LBP", LBP()),
            ("RS_p1/16", RS(p=1.0 / 16)),
            ("RS_p1/64", RS(p=1.0 / 64)),
            ("RS_p1/256", RS(p=1.0 / 256)),
        ]:
            stats = [time_bp(g, sched, max_rounds=max_rounds) for g in graphs]
            s = summarize(stats)
            emit(f"fig2/{dname}/{sched_name}", s["mean_wall_s"] * 1e6,
                 f"conv={s['conv_pct']:.0f}%;rounds={s['mean_rounds']:.0f};"
                 f"updates={s['mean_updates']:.0f}")


# ------------------------------------------------------- relaxation sweep --

def _relaxation_sweep(tiny: bool, full: bool) -> dict:
    """(queues x sample) grid for rlx (+ rlxtree spot) vs exact RBP on hard
    Ising instances; returns the BENCH_tradeoff.json section."""
    from repro.core import RBP, RLX, RLXTree
    from repro.pgm import ising_grid

    from benchmarks.common import emit, graph_set, summarize, time_bp

    if tiny:
        n, n_graphs, max_rounds, p = 10, 2, 1500, 1.0 / 64
        grid = [(4, 0.5), (4, 1.0)]
    elif full:
        n, n_graphs, max_rounds, p = 50, 5, 12000, 1.0 / 256
        grid = [(q, s) for q in (4, 8, 16, 32) for s in (0.25, 0.5, 1.0)]
    else:
        n, n_graphs, max_rounds, p = 24, 4, 8000, 1.0 / 256
        grid = [(q, s) for q in (4, 16) for s in (0.25, 0.5, 1.0)]

    dname = f"ising{n}x{n}_C3.0"
    graphs = graph_set(lambda s: ising_grid(n, 3.0, seed=s), n_graphs)
    section: dict = {"dataset": dname, "n_graphs": n_graphs,
                     "max_rounds": max_rounds, "p": p, "schedulers": {}}

    def measure(label, sched, extra=()):
        stats = [time_bp(g, sched, max_rounds=max_rounds) for g in graphs]
        s = summarize(stats)
        s["conv_frac"] = s.pop("conv_pct") / 100.0
        s.update(extra)
        section["schedulers"][label] = s
        emit(f"relax/{dname}/{label}", max(s["mean_wall_s"], 0.0) * 1e6,
             f"conv={100 * s['conv_frac']:.0f}%;"
             f"rounds={s['mean_rounds']:.0f}")
        return s

    rbp = measure("rbp_exact", RBP(p=p))
    for q, smp in grid:
        measure(f"rlx_q{q}_s{smp}", RLX(queues=q, sample=smp, p=p),
                {"queues": q, "sample": smp})
    measure("rlxtree_q8_s0.5", RLXTree(queues=8, sample=0.5, p=p),
            {"queues": 8, "sample": 0.5})

    # Acceptance: best rlx point within 10% of exact RBP's converged
    # fraction. (On these sizes every relaxation point usually matches RBP
    # at 100%; the margin is for the full-scale run.)
    best_rlx = max(v["conv_frac"] for k, v in section["schedulers"].items()
                   if k.startswith("rlx_"))
    section["rbp_conv_frac"] = rbp["conv_frac"]
    section["best_rlx_conv_frac"] = best_rlx
    section["rlx_within_10pct_of_rbp"] = bool(
        best_rlx >= rbp["conv_frac"] - 0.10)
    return section


# ------------------------------------------------------- collective audit --

def _audit_child() -> None:
    """Runs under 8 forced host devices: compile one sharded BP round per
    scheduler and scan the optimized HLO for cross-shard data movement.

    The discriminating metric is **edge-sized gathers**: all-gather /
    all-to-all instructions whose output holds >= one full edge vector
    (RBP's exact top-k forces XLA to gather the whole residual array to
    every device; the relaxed selection must not). O(Q)-scalar collectives
    -- the per-queue argmax, the convergence vote psum -- are the
    architecture's legitimate traffic and are reported separately."""
    import re

    import jax
    import jax.numpy as jnp

    from repro.core import get_scheduler
    from repro.core import messages as M
    from repro.dist import make_bp_mesh, make_sharded_update, shard_pgm
    from repro.pgm import ising_grid_fast

    mesh = make_bp_mesh()
    update_fn = make_sharded_update(mesh)
    pgm = shard_pgm(ising_grid_fast(16, 2.5, seed=0), mesh)
    n_edges = pgm.n_edges
    report = {"devices": int(mesh.devices.size), "edge_count": n_edges}
    shape_re = re.compile(r"=\s+\w+\[([\d,]*)\]")

    def out_elems(line: str) -> int:
        m = shape_re.search(line)
        if not m:
            return 0
        dims = [int(d) for d in m.group(1).split(",") if d]
        n = 1
        for d in dims:
            n *= d
        return n

    for name in ("rbp", "rlx"):
        sched = get_scheduler(name)
        state = sched.init(pgm)

        def round_fn(logm, rng):
            # One traced BP round, exactly the engine's dataflow: sharded
            # update -> frontier select on the sharded residuals -> commit.
            cand, resid = update_fn(pgm, logm)
            frontier, _ = sched.select(pgm, resid, 1e-3, rng, state,
                                       jnp.int32(1))
            return jnp.where(frontier[:, None], cand, logm)

        logm0 = M.init_messages(pgm)
        txt = (jax.jit(round_fn)
               .lower(logm0, jax.random.key(0)).compile().as_text())
        edge_gathers = small_gathers = 0
        for line in txt.splitlines():
            if " all-gather(" in line or " all-to-all(" in line:
                if out_elems(line) >= n_edges:
                    edge_gathers += 1
                else:
                    small_gathers += 1
        report[name] = {
            "edge_sized_gathers": edge_gathers,
            "small_gathers": small_gathers,
            "sorts": txt.count(" sort("),
            "all-reduce": txt.count("all-reduce"),
        }

    report["eliminates_global_topk"] = bool(
        report["rlx"]["edge_sized_gathers"] == 0
        and report["rlx"]["sorts"] == 0
        and report["rbp"]["edge_sized_gathers"] > 0)
    print("AUDIT_JSON=" + json.dumps(report))


def _run_audit() -> dict:
    env = dict(os.environ,
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "")
                          + " --xla_force_host_platform_device_count=8"))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_tradeoff", "--child-audit"],
        env=env, capture_output=True, text=True, timeout=1200)
    if out.returncode != 0:
        sys.stderr.write(out.stderr[-4000:])
        raise RuntimeError("bench_tradeoff audit child failed")
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("AUDIT_JSON=")][-1]
    return json.loads(line[len("AUDIT_JSON="):])


# ------------------------------------------------------------------ entry --

def _write_record(relax: dict, audit: dict, mode: str) -> None:
    import jax

    from benchmarks.common import emit, out_path

    record = {
        "suite": "tradeoff", "mode": mode,
        "backend": jax.default_backend(), "platform": platform.machine(),
        "unix_time": time.time(),
        "relaxation_sweep": relax,
        "collective_audit": audit,
    }
    with open(out_path("BENCH_tradeoff.json"), "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    emit("relax/audit/eliminates_global_topk", 0.0,
         f"match={audit['eliminates_global_topk']};"
         f"rbp_edge_gathers={audit['rbp']['edge_sized_gathers']};"
         f"rlx_edge_gathers={audit['rlx']['edge_sized_gathers']}")
    emit("relax/acceptance/rlx_within_10pct_of_rbp", 0.0,
         f"match={relax['rlx_within_10pct_of_rbp']};"
         f"rbp={relax['rbp_conv_frac']:.2f};"
         f"rlx={relax['best_rlx_conv_frac']:.2f}")


def run(full: bool = False, n_graphs: int = 5) -> None:
    """benchmarks.run entry: fig2 sweep + relaxation sweep + audit."""
    _fig2(full, n_graphs)
    relax = _relaxation_sweep(tiny=False, full=full)
    _write_record(relax, _run_audit(), "full" if full else "default")


def run_tiny() -> None:
    """CI smoke: minutes-scale relaxation sweep (incl. rlx) + audit; skips
    the fig2 sweep. Same BENCH_tradeoff.json artifact shape."""
    relax = _relaxation_sweep(tiny=True, full=False)
    _write_record(relax, _run_audit(), "tiny")


if __name__ == "__main__":
    if "--child-audit" in sys.argv:
        _audit_child()
    elif "--tiny" in sys.argv:
        run_tiny()
    else:
        run("--full" in sys.argv)
