"""Paper Fig. 2: the parallelism <-> convergence trade-off (GPU RS vs LBP).

Sweeps the frontier multiplier p for Residual Splash on Ising and chain
datasets, reporting cumulative convergence % and speed. Expected
reproduction: lower p => more graphs converge, but slower (more rounds);
LBP (p = full) is fastest where it converges at all.
"""

from __future__ import annotations

from repro.core import LBP, RS
from repro.pgm import chain_graph, ising_grid

from benchmarks.common import emit, graph_set, summarize, time_bp


def run(full: bool = False, n_graphs: int = 5) -> None:
    n = 100 if full else 40
    chain_n = 100_000 if full else 10_000
    datasets = [
        (f"ising{n}x{n}_C2.5", lambda s: ising_grid(n, 2.5, seed=s)),
        (f"chain{chain_n}_C10", lambda s: chain_graph(chain_n, seed=s)),
    ]
    max_rounds = 8000 if full else 4000
    for dname, factory in datasets:
        graphs = graph_set(factory, n_graphs)
        for sched_name, sched in [
            ("LBP", LBP()),
            ("RS_p1/16", RS(p=1.0 / 16)),
            ("RS_p1/64", RS(p=1.0 / 64)),
            ("RS_p1/256", RS(p=1.0 / 256)),
        ]:
            stats = [time_bp(g, sched, max_rounds=max_rounds) for g in graphs]
            s = summarize(stats)
            emit(f"fig2/{dname}/{sched_name}", s["mean_wall_s"] * 1e6,
                 f"conv={s['conv_pct']:.0f}%;rounds={s['mean_rounds']:.0f};"
                 f"updates={s['mean_updates']:.0f}")
