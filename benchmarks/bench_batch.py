"""Batched multi-graph engine: graphs/sec, single vs batched, evacuation.

Three regimes, all reported (and persisted to ``benchmarks/out/
BENCH_batch.json`` so the perf trajectory accumulates in CI artifacts):

- **serving (cold)**: a mixed-size request stream where (nearly) every graph
  has a distinct padded shape -- the realistic serving case on XLA, where
  the naive per-request loop pays one compilation per shape while the
  bucketed engine pays one per bucket. This is where batching wins big on
  any backend, and it is the headline graphs/sec number.
- **steady state (warm)**: same stream, compile caches hot. On a 1-2 core
  CPU the update is compute-bound (no idle lanes to fill), so the batched
  engine's whole-bucket rounds cost roughly ``B`` naive rounds and
  stragglers set the round count: expect <= 1x here. On a many-core device
  the same fold is what saturates the hardware -- the paper's premise; the
  number is reported to keep the CPU trajectory honest.
- **straggler evacuation**: a same-shape stream with one graph that stalls
  to ``max_rounds`` (LBP on a hard Ising instance). ``BPEngine.serve``
  evacuates converged graphs between chunks and backfills from the pending
  queue; total and wasted device sweeps must drop vs. the run-every-
  bucket-to-completion baseline (the PR-1 behavior).
- **async serving**: the same straggler stream through the pipeline
  (``repro.core.serving``). Evacuation-only still pays for dead slots after
  the pending queue drains; bucket *compaction* re-buckets survivors into
  narrower batches, so its wasted sweeps must drop further. The pipeline's
  per-request records also give queue-to-result latency percentiles -- the
  serving-facing metric the aggregate numbers hide.
- **admission policies**: a mixed-effort straggler stream (every 4th
  request stalls toward max_rounds) served FIFO vs ``residual`` admission
  at equal slots -- co-batching by expected effort must not increase (and
  should roughly halve) wasted sweeps at identical useful work -- plus a
  bursty-arrival run comparing FIFO against ``windowed`` admission
  (fuller buckets bought with admission wait, reported separately from
  device time) with the threaded ingestion feeder pulling the bursty
  source.
"""

from __future__ import annotations

import json
import math
import platform
import time

import jax
import numpy as np

from repro.core import BPConfig, BPEngine, RnBP, serve_async
from repro.pgm import ising_grid
from benchmarks.common import (emit, mixed_graph_set, out_path,
                               time_serving_batched, time_serving_loop)


def _straggler_section(record: dict) -> None:
    # LBP is deterministic; ising(10, 3.5, seed=1) stalls to max_rounds
    # while the C=1.5 instances converge in tens of rounds.
    fast = [ising_grid(10, 1.5, seed=s) for s in range(19)]
    stream = fast[:5] + [ising_grid(10, 3.5, seed=1)] + fast[5:]
    engine = BPEngine(BPConfig(scheduler="lbp", eps=1e-5, max_rounds=384,
                               history=False))
    kw = dict(max_batch=4, chunk_rounds=48)
    evac = engine.serve(stream, jax.random.key(0), evacuate=True, **kw).stats
    base = engine.serve(stream, jax.random.key(0), evacuate=False, **kw).stats
    emit("batch/straggler/evacuate", evac.device_sweeps,
         f"wasted={evac.wasted_sweeps};backfilled={evac.backfilled}")
    emit("batch/straggler/baseline", base.device_sweeps,
         f"wasted={base.wasted_sweeps};"
         f"sweep_ratio={evac.device_sweeps / base.device_sweeps:.3f}")
    record["straggler_evacuation"] = {
        "evac_device_sweeps": evac.device_sweeps,
        "evac_wasted_sweeps": evac.wasted_sweeps,
        "evac_backfilled": evac.backfilled,
        "baseline_device_sweeps": base.device_sweeps,
        "baseline_wasted_sweeps": base.wasted_sweeps,
        "sweep_ratio": evac.device_sweeps / base.device_sweeps,
    }


def _async_serving_section(record: dict) -> None:
    # Same straggler construction as above: after the queue drains, the
    # straggler holds a width-4 bucket whose other 3 slots are dead weight
    # that evacuation alone cannot shed -- compaction's target term.
    fast = [ising_grid(10, 1.5, seed=s) for s in range(19)]
    stream = fast[:5] + [ising_grid(10, 3.5, seed=1)] + fast[5:]
    engine = BPEngine(BPConfig(scheduler="lbp", eps=1e-5, max_rounds=384,
                               history=False))
    kw = dict(max_batch=4, chunk_rounds=48)

    # Both arms run slots=1 so the wasted-sweep ratio isolates compaction
    # (slot count changes admission/accounting on its own).
    t0 = time.perf_counter()
    evac = serve_async(engine, stream, jax.random.key(0), compact=False,
                       slots=1, **kw)
    t_evac = time.perf_counter() - t0
    t0 = time.perf_counter()
    comp = serve_async(engine, stream, jax.random.key(0), compact=True,
                       slots=1, **kw)
    t_comp = time.perf_counter() - t0

    pct = comp.latency_percentiles((50, 90, 99))
    wasted_ratio = (comp.stats.wasted_sweeps
                    / max(evac.stats.wasted_sweeps, 1))
    emit("batch/async/evac_only", evac.stats.device_sweeps,
         f"wasted={evac.stats.wasted_sweeps}")
    emit("batch/async/compacted", comp.stats.device_sweeps,
         f"wasted={comp.stats.wasted_sweeps};"
         f"wasted_ratio={wasted_ratio:.3f};"
         f"compactions={comp.stats.compactions}")
    emit("batch/async/latency_ms", pct["p50"],
         f"p90={pct['p90']:.1f};p99={pct['p99']:.1f}")
    record["async_serving"] = {
        "evac_only_device_sweeps": evac.stats.device_sweeps,
        "evac_only_wasted_sweeps": evac.stats.wasted_sweeps,
        "evac_only_wall_s": t_evac,
        "compact_device_sweeps": comp.stats.device_sweeps,
        "compact_wasted_sweeps": comp.stats.wasted_sweeps,
        "compact_wall_s": t_comp,
        "compactions": comp.stats.compactions,
        "compaction_log": comp.stats.compaction_log,
        "wasted_sweep_ratio": wasted_ratio,
        "graphs_per_s": len(stream) / t_comp,
        "latency_ms": pct,
    }


def _admission_section(record: dict) -> None:
    # Mixed-effort, one shape family: every 4th request stalls toward
    # max_rounds. FIFO admission mixes effort classes, so every chunk pays
    # dead iterations on slots whose graphs finished mid-chunk; residual
    # admission co-batches similar-effort requests. Equal slots, equal
    # useful work -- only the waste moves.
    fast = [ising_grid(10, 1.5, seed=s) for s in range(16)]
    slow = [ising_grid(10, 3.5, seed=s) for s in range(4)]
    stream, fi, si = [], 0, 0
    for i in range(20):
        if i % 5 == 3:
            stream.append(slow[si]); si += 1
        else:
            stream.append(fast[fi]); fi += 1
    engine = BPEngine(BPConfig(scheduler="lbp", eps=1e-5, max_rounds=384,
                               history=False))
    kw = dict(max_batch=4, chunk_rounds=48, slots=1, compact=False,
              prefetch=None)
    fifo = serve_async(engine, stream, jax.random.key(0),
                       admission="fifo", **kw)
    resid = serve_async(engine, stream, jax.random.key(0),
                        admission="residual", **kw)
    assert resid.stats.useful_sweeps == fifo.stats.useful_sweeps
    wasted_ratio = (resid.stats.wasted_sweeps
                    / max(fifo.stats.wasted_sweeps, 1))
    emit("batch/admission/fifo", fifo.stats.device_sweeps,
         f"wasted={fifo.stats.wasted_sweeps}")
    emit("batch/admission/residual", resid.stats.device_sweeps,
         f"wasted={resid.stats.wasted_sweeps};"
         f"wasted_ratio={wasted_ratio:.3f}")

    # Bursty arrivals through the threaded feeder: windowed admission
    # gathers fuller buckets (admission_widths) at the price of admission
    # wait, which the percentile split reports separately from device time.
    def bursty():
        for i, p in enumerate(fast[:12]):
            if i % 4 == 0 and i:
                time.sleep(0.004)
            yield p

    bkw = dict(max_batch=4, chunk_rounds=48, slots=1, prefetch=2,
               ingest_threads=2)
    fifo_b = serve_async(engine, bursty(), jax.random.key(0), **bkw)
    wind_b = serve_async(engine, bursty(), jax.random.key(0),
                         admission="windowed",
                         admission_kwargs={"window_s": 0.05}, **bkw)
    f_wait = fifo_b.latency_percentiles((50,), field="admission")["p50"]
    w_wait = wind_b.latency_percentiles((50,), field="admission")["p50"]
    emit("batch/admission/windowed_widths",
         float(np.mean(wind_b.stats.admission_widths)),
         f"fifo_mean_width={np.mean(fifo_b.stats.admission_widths):.2f};"
         f"wait_p50_ms={w_wait:.1f};fifo_wait_p50_ms={f_wait:.1f}")
    record["admission_policies"] = {
        "fifo_device_sweeps": fifo.stats.device_sweeps,
        "fifo_wasted_sweeps": fifo.stats.wasted_sweeps,
        "residual_device_sweeps": resid.stats.device_sweeps,
        "residual_wasted_sweeps": resid.stats.wasted_sweeps,
        "useful_sweeps": resid.stats.useful_sweeps,
        "wasted_sweep_ratio": wasted_ratio,
        "bursty_fifo_widths": fifo_b.stats.admission_widths,
        "bursty_windowed_widths": wind_b.stats.admission_widths,
        "bursty_windowed_holds": wind_b.stats.admission_holds,
        "bursty_fifo_admission_wait_p50_ms": f_wait,
        "bursty_windowed_admission_wait_p50_ms": w_wait,
    }


def run(full: bool = False, n_graphs: int = 0) -> None:
    n = n_graphs or (32 if full else 16)
    pgms = mixed_graph_set(n)
    sched = RnBP(low_p=0.4, high_p=0.9)
    rng = jax.random.key(0)
    kw = dict(eps=1e-3, max_rounds=2000)

    # --- cold: compile-inclusive, fresh shapes (one process = one cold run)
    t_naive_cold = time_serving_loop(pgms, sched, rng, **kw)
    t_batch_cold = time_serving_batched(pgms, sched, rng, growth=math.inf,
                                        **kw)
    # --- warm: caches hot, steady-state throughput
    t_naive_warm = time_serving_loop(pgms, sched, rng, **kw)
    t_batch_warm = time_serving_batched(pgms, sched, rng, growth=math.inf,
                                        **kw)

    rows = {
        "serving_cold": (t_naive_cold, t_batch_cold),
        "steady_warm": (t_naive_warm, t_batch_warm),
    }
    record = {
        "suite": "batch",
        "n_graphs": n,
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "platform": platform.machine(),
        "unix_time": time.time(),
    }
    for name, (t_naive, t_batch) in rows.items():
        naive_gps, batch_gps = n / t_naive, n / t_batch
        emit(f"batch/{name}/naive", t_naive / n * 1e6,
             f"graphs_per_s={naive_gps:.2f}")
        emit(f"batch/{name}/batched", t_batch / n * 1e6,
             f"graphs_per_s={batch_gps:.2f};speedup={t_naive / t_batch:.2f}")
        record[name] = {
            "naive_s": t_naive, "batched_s": t_batch,
            "naive_graphs_per_s": naive_gps,
            "batched_graphs_per_s": batch_gps,
            "speedup": t_naive / t_batch,
        }

    _straggler_section(record)
    _async_serving_section(record)
    _admission_section(record)

    with open(out_path("BENCH_batch.json"), "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
