"""Batched multi-graph engine: graphs/sec, single vs batched.

Two regimes, both reported (and persisted to ``BENCH_batch.json`` so the
perf trajectory accumulates in CI artifacts):

- **serving (cold)**: a mixed-size request stream where (nearly) every graph
  has a distinct padded shape -- the realistic serving case on XLA, where
  the naive per-request loop pays one compilation per shape while the
  bucketed engine pays one per bucket. This is where batching wins big on
  any backend, and it is the headline graphs/sec number.
- **steady state (warm)**: same stream, compile caches hot. On a 1-2 core
  CPU the update is compute-bound (no idle lanes to fill), so the batched
  engine's whole-bucket rounds cost roughly ``B`` naive rounds and
  stragglers set the round count: expect <= 1x here. On a many-core device
  the same fold is what saturates the hardware -- the paper's premise; the
  number is reported to keep the CPU trajectory honest.
"""

from __future__ import annotations

import json
import math
import platform
import time

import jax

from repro.core import RnBP
from benchmarks.common import (emit, mixed_graph_set, time_serving_batched,
                               time_serving_loop)

JSON_PATH = "BENCH_batch.json"


def run(full: bool = False, n_graphs: int = 0) -> None:
    n = n_graphs or (32 if full else 16)
    pgms = mixed_graph_set(n)
    sched = RnBP(low_p=0.4, high_p=0.9)
    rng = jax.random.key(0)
    kw = dict(eps=1e-3, max_rounds=2000)

    # --- cold: compile-inclusive, fresh shapes (one process = one cold run)
    t_naive_cold = time_serving_loop(pgms, sched, rng, **kw)
    t_batch_cold = time_serving_batched(pgms, sched, rng, growth=math.inf,
                                        **kw)
    # --- warm: caches hot, steady-state throughput
    t_naive_warm = time_serving_loop(pgms, sched, rng, **kw)
    t_batch_warm = time_serving_batched(pgms, sched, rng, growth=math.inf,
                                        **kw)

    rows = {
        "serving_cold": (t_naive_cold, t_batch_cold),
        "steady_warm": (t_naive_warm, t_batch_warm),
    }
    record = {
        "suite": "batch",
        "n_graphs": n,
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "platform": platform.machine(),
        "unix_time": time.time(),
    }
    for name, (t_naive, t_batch) in rows.items():
        naive_gps, batch_gps = n / t_naive, n / t_batch
        emit(f"batch/{name}/naive", t_naive / n * 1e6,
             f"graphs_per_s={naive_gps:.2f}")
        emit(f"batch/{name}/batched", t_batch / n * 1e6,
             f"graphs_per_s={batch_gps:.2f};speedup={t_naive / t_batch:.2f}")
        record[name] = {
            "naive_s": t_naive, "batched_s": t_batch,
            "naive_graphs_per_s": naive_gps,
            "batched_graphs_per_s": batch_gps,
            "speedup": t_naive / t_batch,
        }

    with open(JSON_PATH, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
