"""Router tier: aggregate throughput vs replica count + work stealing.

Two measurements over 6x6 Ising streams (one padded shape: ``C=1.5``
converges in ~25 LBP rounds, ``C=3.0`` takes ~5x that, ``C=3.5`` never
converges within the 480-round budget -- the fast/straggler mixes the
serving tier exists for):

- **scaling**: graphs/sec through ``repro.serve.Router`` at 1/2/4
  replicas, ``round_robin``, stealing off. On this container every replica
  thread shares one CPU core, so expect ~flat-to-<=1x aggregate throughput
  (same honest story as BENCH_dist.json); the row records the trajectory
  so a many-core run slots into the same file. The hardware-independent
  payload is the determinism column: per-request results are bitwise
  replica-count-invariant, so the sweep re-checks result equality across
  fleet sizes.
- **stealing**: 2 replicas, a deliberately skewed placement (replica 0
  gets one non-converging straggler co-batched with one fast request,
  replica 1 gets a deep all-fast backlog), with the stream held open past
  the straggler's runtime, as a sustained online stream would be.
  Stealing off: once replica 0's fast graph evacuates, the freed lane has
  no pending work to backfill and compaction cannot trigger while the
  stream is open, so the lane sweeps dead alongside the straggler for its
  remaining ~455 rounds -- a deterministic wasted-sweep floor. Stealing
  on: the starving replica repeatedly pulls fast requests from the peer's
  inbox tail and backfills them into that same lane. The metric is wasted
  (dead-slot) sweeps -- timing-robust on a shared core, unlike wall time
  -- and results stay bitwise identical either way. The scenario pins the
  knobs that make the dead lane real: ``slots=1`` (stolen work must
  backfill the straggler bucket, not open a fresh one), windowed
  admission (the straggler and the fast co-batch deterministically
  instead of racing into two width-1 buckets), and a victim with no
  straggler of its own (so stealing taps surplus, rather than moving the
  dead lane across the tier).

Every configuration runs once untimed first: a replica fleet's compile
profile depends on its share sizes (straggler-tail compaction widths), so
per-engine warmup alone does not cover it.
"""

from __future__ import annotations

import json
import platform
import time

import jax
import numpy as np

from benchmarks.common import emit, out_path
from repro.core import BPConfig, BPEngine
from repro.pgm import ising_grid
from repro.serve import RoutingPolicy, serve_routed

EPS = 1e-5
ROUNDS = 480        # C=3.5 stalls to this budget; C=1.5 converges ~25
PIPE = dict(max_batch=2, chunk_rounds=16, slots=2, prefetch=2,
            ingest_queue=1)


class _Skew(RoutingPolicy):
    """Adversarial placement for the stealing measurement: the first
    ``thief_share`` requests land on replica 0, everything after on
    replica 1 -- a hotspot no load-aware policy would create, isolating
    the stealing path itself."""

    name = "skew"

    def __init__(self, thief_share: int):
        super().__init__()
        self.thief_share = thief_share

    def pick(self, rid, kind, loads):
        return 0 if rid < self.thief_share else 1


def _stream(n_fast: int, n_heavy: int):
    """Interleaved fast/straggler 6x6 grids (one padded shape)."""
    fast = [ising_grid(6, 1.5, seed=s) for s in range(n_fast)]
    heavy = [ising_grid(6, 3.0, seed=s) for s in range(n_heavy)]
    out = []
    while fast or heavy:
        if heavy:
            out.append(heavy.pop())
        if fast:
            out.append(fast.pop())
    return out


def _held_open(pgms, hold_s: float):
    """Yield everything at once, then keep the stream open ``hold_s``
    before signalling exhaustion -- a sustained online stream from the
    replicas' point of view (their sources see no end-of-stream, so
    compaction cannot narrow a starving replica's bucket and mask its
    dead-slot sweeps inside the window)."""
    yield from pgms
    time.sleep(hold_s)


def _fingerprint(results):
    return [np.asarray(r.logm).tobytes() for r in results]


def run(full: bool = False, n_graphs: int = 0) -> None:
    """Emit router scaling + stealing rows; write BENCH_router.json."""
    n = n_graphs or (24 if full else 12)
    cfg = BPConfig(scheduler="lbp", eps=EPS, max_rounds=ROUNDS,
                   history=False)
    engines = [BPEngine(cfg) for _ in range(4)]
    stream = _stream(n_fast=n - n // 3, n_heavy=n // 3)
    rng = jax.random.key(0)

    record = {
        "suite": "router", "graphs": len(stream),
        "heavy": n // 3, "backend": jax.default_backend(),
        "platform": platform.machine(), "unix_time": time.time(),
        "note": ("replica threads share one CPU core on CI, so aggregate "
                 "graphs/sec is ~flat (honest <=1x, as in BENCH_dist); "
                 "determinism and dead-slot-sweep columns are the "
                 "hardware-independent payload"),
        "scaling": {}, "stealing": {},
    }

    base_fp = None
    base_gps = None
    for n_rep in (1, 2, 4):
        serve_routed(engines[:n_rep], stream, rng,            # warm/compile
                     routing="round_robin", steal=False, **PIPE)
        t0 = time.perf_counter()
        rep = serve_routed(engines[:n_rep], stream, rng,
                           routing="round_robin", steal=False, **PIPE)
        wall = time.perf_counter() - t0
        fp = _fingerprint(rep.results)
        gps = len(stream) / wall
        if base_fp is None:
            base_fp, base_gps = fp, gps
        match = fp == base_fp
        emit(f"router/scaling/replicas{n_rep}", 1e6 * wall / len(stream),
             f"graphs_per_s={gps:.2f};speedup_vs_1={gps / base_gps:.2f};"
             f"bitwise_vs_1={match}")
        record["scaling"][str(n_rep)] = {
            "wall_s": wall, "graphs_per_s": gps,
            "speedup_vs_1": gps / base_gps, "bitwise_vs_1": bool(match),
            "wasted_sweeps": rep.wasted_sweeps,
        }

    # Stealing: replica 0 gets [straggler, fast] (windowed admission
    # co-batches them); once the fast graph evacuates (~25 rounds in) its
    # lane is dead for the straggler's remaining ~455 rounds unless it
    # backfills work stolen from replica 1's deep fast-only inbox. The
    # stream is held open across that window (an exhausted stream would
    # let compaction narrow the bucket and rescue the stealing-off case
    # -- hiding the effect measured). Identical fast graphs keep pairing
    # waste at zero, so the off-case floor is deterministic.
    fast = ising_grid(6, 1.5, seed=0)
    skew_stream = [ising_grid(6, 3.5, seed=100), fast] + [fast] * 30
    skew_kw = dict(PIPE, slots=1, admission="windowed",
                   admission_kwargs={"window_s": 0.25})
    hold = 3.0 if full else 2.0
    steal_fp = {}
    for steal in (False, True):
        serve_routed(engines[:2], _held_open(skew_stream, hold), rng,
                     routing=_Skew(2), steal=steal, steal_batch=4,
                     low_watermark=2, **skew_kw)              # warm/compile
        t0 = time.perf_counter()
        rep = serve_routed(engines[:2], _held_open(skew_stream, hold), rng,
                           routing=_Skew(2), steal=steal, steal_batch=4,
                           low_watermark=2, **skew_kw)
        wall = time.perf_counter() - t0
        steal_fp[steal] = _fingerprint(rep.results)
        mode = "on" if steal else "off"
        emit(f"router/steal_{mode}", 1e6 * wall / len(skew_stream),
             f"wasted_sweeps={rep.wasted_sweeps};"
             f"useful_sweeps={rep.useful_sweeps};"
             f"steals={rep.stats.steals};stolen={rep.stats.stolen}")
        record["stealing"][mode] = {
            "wall_s": wall, "wasted_sweeps": rep.wasted_sweeps,
            "useful_sweeps": rep.useful_sweeps,
            "device_sweeps": rep.device_sweeps,
            "steals": rep.stats.steals, "stolen": rep.stats.stolen,
        }
    off, on = record["stealing"]["off"], record["stealing"]["on"]
    record["stealing"]["bitwise_on_vs_off"] = (
        steal_fp[True] == steal_fp[False])
    record["stealing"]["wasted_sweep_reduction"] = (
        off["wasted_sweeps"] - on["wasted_sweeps"])
    emit("router/steal_effect", 0.0,
         f"wasted_off={off['wasted_sweeps']};wasted_on={on['wasted_sweeps']};"
         f"bitwise={record['stealing']['bitwise_on_vs_off']}")

    with open(out_path("BENCH_router.json"), "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")


if __name__ == "__main__":
    import sys
    run("--full" in sys.argv)
